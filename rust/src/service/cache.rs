//! The **persistent result cache**: a versioned, corruption-tolerant
//! on-disk store of finished search jobs, keyed by canonical job
//! signature.
//!
//! Format: a JSON-lines file whose first line is the version header
//! `{"union_result_cache":1}` and whose remaining lines are one record
//! per completed job. Records are *appended* as jobs finish (one
//! `write` + `flush` per job — the file is never rewritten in steady
//! state), so a crash can at worst truncate the final record.
//! [`ResultCache::open`] therefore loads leniently: a line that fails
//! to parse, fails validation, or is half-written is **skipped and
//! counted**, never fatal. A version-mismatched or headerless file is
//! preserved as `<path>.bad-vN` and a fresh store is started — old data
//! is never silently destroyed, and never misinterpreted.
//!
//! Scores and cost metrics are serialized with shortest-round-trip
//! float formatting ([`Json`]), so a reloaded record reproduces the
//! original `f64`s bit for bit — a cache hit is indistinguishable from
//! re-running the search (`tests/service.rs` pins this).

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::mappers::SearchResult;
use crate::mapping::Mapping;

use super::proto::{mapping_from_json, mapping_to_json, Json};

/// On-disk format version; bump when the record schema changes.
pub const CACHE_VERSION: u64 = 1;

/// One completed job: the best mapping plus the summary metrics a
/// service response carries. (The full per-level cost breakdown is not
/// stored — responses report summary metrics, and a client that wants
/// the breakdown can `evaluate` the returned mapping.)
#[derive(Debug, Clone, PartialEq)]
pub struct CachedResult {
    pub score: f64,
    pub mapping: Mapping,
    pub cycles: f64,
    pub energy_pj: f64,
    pub utilization: f64,
    pub macs: u64,
    pub clock_ghz: f64,
    /// Candidates scored by the search that produced this result.
    pub evaluated: usize,
}

impl CachedResult {
    /// Snapshot a finished [`SearchResult`].
    pub fn from_search(r: &SearchResult) -> CachedResult {
        CachedResult {
            score: r.score,
            mapping: r.mapping.clone(),
            cycles: r.cost.cycles,
            energy_pj: r.cost.energy_pj,
            utilization: r.cost.utilization,
            macs: r.cost.macs,
            clock_ghz: r.cost.clock_ghz,
            evaluated: r.evaluated,
        }
    }

    /// Energy in joules (mirrors `CostEstimate::energy_j`).
    pub fn energy_j(&self) -> f64 {
        self.energy_pj * 1e-12
    }

    /// Latency in seconds.
    pub fn latency_s(&self) -> f64 {
        self.cycles / (self.clock_ghz * 1e9)
    }

    fn to_json(&self, sig: &str) -> Json {
        Json::Obj(vec![
            ("sig".into(), Json::Str(sig.to_string())),
            ("score".into(), Json::Num(self.score)),
            ("cycles".into(), Json::Num(self.cycles)),
            ("energy_pj".into(), Json::Num(self.energy_pj)),
            ("utilization".into(), Json::Num(self.utilization)),
            ("macs".into(), Json::Num(self.macs as f64)),
            ("clock_ghz".into(), Json::Num(self.clock_ghz)),
            ("evaluated".into(), Json::Num(self.evaluated as f64)),
            ("mapping".into(), mapping_to_json(&self.mapping)),
        ])
    }

    fn from_json(doc: &Json) -> Result<(String, CachedResult), String> {
        let sig = doc.str("sig").ok_or("record has no sig")?.to_string();
        let need = |k: &str| doc.num(k).ok_or_else(|| format!("record field '{k}' missing"));
        let mapping =
            mapping_from_json(doc.get("mapping").ok_or("record has no mapping")?)?;
        if mapping.levels.is_empty() {
            return Err("record mapping has no levels".into());
        }
        Ok((
            sig,
            CachedResult {
                score: need("score")?,
                cycles: need("cycles")?,
                energy_pj: need("energy_pj")?,
                utilization: need("utilization")?,
                macs: doc.u64_field("macs").ok_or("record field 'macs' missing")?,
                clock_ghz: need("clock_ghz")?,
                evaluated: doc.u64_field("evaluated").unwrap_or(0) as usize,
                mapping,
            },
        ))
    }
}

/// Load/append statistics, surfaced by `union client status` and the
/// corruption-tolerance tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Records loaded at open.
    pub loaded: usize,
    /// Lines skipped at open (corrupt, truncated, or invalid records).
    pub skipped: usize,
    /// Records appended since open.
    pub appended: usize,
}

/// The persistent store. `None` path = purely in-memory (tests, or
/// `union serve` without `--cache`).
pub struct ResultCache {
    path: Option<PathBuf>,
    file: Option<File>,
    map: HashMap<String, CachedResult>,
    stats: CacheStats,
}

impl ResultCache {
    /// An in-memory cache: same dedup behavior, nothing persisted.
    pub fn in_memory() -> ResultCache {
        ResultCache { path: None, file: None, map: HashMap::new(), stats: CacheStats::default() }
    }

    /// Open (or create) the store at `path`, loading every valid record.
    /// Unreadable *records* are skipped (see module docs); an unreadable
    /// *file* — wrong version, missing header — is set aside as
    /// `<path>.bad-vN` and a fresh store is started. Only a real I/O
    /// error (permissions, missing parent directory) is fatal.
    pub fn open(path: &Path) -> Result<ResultCache, String> {
        let mut map = HashMap::new();
        let mut stats = CacheStats::default();
        let mut needs_header = true;
        let mut needs_newline_repair = false;
        match std::fs::read_to_string(path) {
            Ok(text) => {
                // a crash mid-append can leave a half-written final line
                // with no newline; appending onto it would fuse (and
                // destroy) the next record, so terminate it first
                needs_newline_repair = !text.is_empty() && !text.ends_with('\n');
                let mut lines = text.lines();
                let header_ok = lines
                    .next()
                    .and_then(|l| Json::parse(l).ok())
                    .and_then(|h| h.u64_field("union_result_cache"))
                    == Some(CACHE_VERSION);
                if header_ok {
                    needs_header = false;
                    for line in lines {
                        if line.trim().is_empty() {
                            continue;
                        }
                        match Json::parse(line).and_then(|doc| CachedResult::from_json(&doc)) {
                            Ok((sig, rec)) => {
                                // identical jobs are deterministic, so
                                // duplicate records agree; first wins
                                map.entry(sig).or_insert(rec);
                                stats.loaded += 1;
                            }
                            Err(_) => stats.skipped += 1,
                        }
                    }
                } else if !text.trim().is_empty() {
                    // wrong version / not a cache file: set it aside
                    // rather than appending v1 records into it. The
                    // aside name keeps the full filename and never
                    // overwrites an earlier set-aside.
                    let file_name = path
                        .file_name()
                        .map(|n| n.to_string_lossy().into_owned())
                        .unwrap_or_else(|| "cache".into());
                    let mut aside = path.with_file_name(format!(
                        "{file_name}.bad-v{CACHE_VERSION}"
                    ));
                    let mut n = 1usize;
                    while aside.exists() {
                        aside = path.with_file_name(format!(
                            "{file_name}.bad-v{CACHE_VERSION}.{n}"
                        ));
                        n += 1;
                    }
                    std::fs::rename(path, &aside).map_err(|e| {
                        format!("cannot set aside incompatible cache {}: {e}", path.display())
                    })?;
                }
                // an existing-but-empty file still needs its header
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(format!("reading cache {}: {e}", path.display())),
        }
        // (re)create with a header if absent, empty or set aside
        if needs_header {
            let mut f = File::create(path)
                .map_err(|e| format!("creating cache {}: {e}", path.display()))?;
            let header = Json::Obj(vec![(
                "union_result_cache".into(),
                Json::Num(CACHE_VERSION as f64),
            )]);
            writeln!(f, "{}", header.to_line())
                .map_err(|e| format!("writing cache header: {e}"))?;
        }
        let mut file = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| format!("opening cache {} for append: {e}", path.display()))?;
        if needs_newline_repair && !needs_header {
            writeln!(file).map_err(|e| format!("repairing cache tail: {e}"))?;
        }
        Ok(ResultCache {
            path: Some(path.to_path_buf()),
            file: Some(file),
            map,
            stats,
        })
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Distinct signatures currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, sig: &str) -> Option<&CachedResult> {
        self.map.get(sig)
    }

    /// Record a completed job: insert in memory and append one line to
    /// the store (flushed immediately; an append failure is reported on
    /// stderr but never loses the in-memory entry or fails the job).
    pub fn insert(&mut self, sig: &str, result: CachedResult) {
        if self.map.contains_key(sig) {
            return; // deterministic duplicates; keep the first record
        }
        if let Some(f) = self.file.as_mut() {
            let line = result.to_json(sig).to_line();
            if let Err(e) = writeln!(f, "{line}").and_then(|()| f.flush()) {
                eprintln!("result cache: append failed: {e}");
            } else {
                self.stats.appended += 1;
            }
        }
        self.map.insert(sig.to_string(), result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::LevelMapping;

    fn sample_result(seed: u64) -> CachedResult {
        CachedResult {
            score: 1.0 / (seed as f64 + 3.0),
            mapping: Mapping {
                levels: vec![LevelMapping {
                    temporal_order: vec![0, 1],
                    temporal_tile: vec![seed + 1, 4],
                    spatial_tile: vec![1, 4],
                }],
            },
            cycles: 123.5 * seed as f64,
            energy_pj: 9.75e4,
            utilization: 0.5,
            macs: 1 << 20,
            clock_ghz: 1.0,
            evaluated: 600,
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        static NEXT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "union-cache-test-{}-{tag}-{n}.jsonl",
            std::process::id()
        ))
    }

    #[test]
    fn record_roundtrip_is_bit_identical() {
        let r = sample_result(7);
        let line = r.to_json("sig|x").to_line();
        let (sig, back) = CachedResult::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(sig, "sig|x");
        assert_eq!(back.score.to_bits(), r.score.to_bits());
        assert_eq!(back.cycles.to_bits(), r.cycles.to_bits());
        assert_eq!(back, r);
    }

    #[test]
    fn persists_across_reopen() {
        let path = tmp_path("reopen");
        {
            let mut c = ResultCache::open(&path).unwrap();
            c.insert("a", sample_result(1));
            c.insert("b", sample_result(2));
            assert_eq!(c.stats().appended, 2);
        }
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().loaded, 2);
        assert_eq!(c.stats().skipped, 0);
        assert_eq!(c.get("a").unwrap(), &sample_result(1));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_mismatch_sets_file_aside() {
        let path = tmp_path("badver");
        let bad = "{\"union_result_cache\":99}\n{\"sig\":\"x\"}\n";
        std::fs::write(&path, bad).unwrap();
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 0);
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let aside = path.with_file_name(format!("{name}.bad-v1"));
        assert!(aside.exists(), "old file preserved (full filename kept)");
        // a second incompatible file must not overwrite the first aside
        drop(c);
        std::fs::write(&path, bad).unwrap();
        let _ = ResultCache::open(&path).unwrap();
        let aside2 = path.with_file_name(format!("{name}.bad-v1.1"));
        assert!(aside.exists() && aside2.exists(), "both asides preserved");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&aside).ok();
        std::fs::remove_file(&aside2).ok();
    }

    #[test]
    fn in_memory_cache_never_touches_disk() {
        let mut c = ResultCache::in_memory();
        c.insert("a", sample_result(1));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().appended, 0);
        assert!(c.path().is_none());
    }
}
