//! The **tiered persistent result cache**: a bounded in-memory warm
//! tier in front of a versioned, corruption-tolerant on-disk store of
//! finished search jobs, keyed by canonical job signature.
//!
//! On-disk format: a JSON-lines file whose first line is the version
//! header `{"union_result_cache":1}` and whose remaining lines are one
//! record per completed job. Records are *appended* as jobs finish, in
//! **batches** (every [`CacheConfig::flush_every`] records or
//! [`CacheConfig::flush_after`], whichever comes first — the service
//! ticks the timer), so a crash can at worst lose the unflushed tail;
//! it can never tear a previously flushed line. [`ResultCache::open`]
//! loads leniently: a line that fails to parse, fails validation, or is
//! half-written is **skipped and counted**, never fatal. A
//! version-mismatched or headerless file is preserved as
//! `<path>.bad-vN` and a fresh store is started — old data is never
//! silently destroyed, and never misinterpreted.
//!
//! In memory the store is **tiered** rather than fully resident:
//!
//! 1. **warm** — a [`LruCache`] bounded by entry count *and*
//!    approximate bytes ([`CacheConfig::warm_entries`] /
//!    [`CacheConfig::warm_bytes`]), so a service over a multi-gigabyte
//!    cache file has bounded resident memory;
//! 2. **pending** — records accepted but not yet flushed to disk
//!    (bounded by `flush_every`);
//! 3. **cold** — everything else lives only in a signature → file
//!    offset index; a cold hit seeks and re-parses the one line, which
//!    reproduces the original `f64`s bit for bit (shortest-round-trip
//!    float formatting in [`Json`]), then re-warms the entry.
//!
//! **Log compaction**: open rewrites the file (temp file + rename)
//! whenever it holds reclaimable lines — duplicate signatures (the
//! newest record per signature is kept), corrupt/torn lines, blanks —
//! and [`ResultCache::flush`] triggers the same rewrite past a size
//! threshold. Compaction copies surviving lines verbatim, so answers
//! after compaction are byte-identical to before (pinned by tests).

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::mappers::SearchResult;
use crate::mapping::Mapping;
use crate::util::lru::LruCache;

use super::proto::{mapping_from_json, mapping_to_json, Json};

/// On-disk format version; bump when the record schema changes.
pub const CACHE_VERSION: u64 = 1;

/// One completed job: the best mapping plus the summary metrics a
/// service response carries. (The full per-level cost breakdown is not
/// stored — responses report summary metrics, and a client that wants
/// the breakdown can `evaluate` the returned mapping.)
#[derive(Debug, Clone, PartialEq)]
pub struct CachedResult {
    pub score: f64,
    pub mapping: Mapping,
    pub cycles: f64,
    pub energy_pj: f64,
    pub utilization: f64,
    pub macs: u64,
    pub clock_ghz: f64,
    /// Candidates scored by the search that produced this result.
    pub evaluated: usize,
}

impl CachedResult {
    /// Snapshot a finished [`SearchResult`].
    pub fn from_search(r: &SearchResult) -> CachedResult {
        CachedResult {
            score: r.score,
            mapping: r.mapping.clone(),
            cycles: r.cost.cycles,
            energy_pj: r.cost.energy_pj,
            utilization: r.cost.utilization,
            macs: r.cost.macs,
            clock_ghz: r.cost.clock_ghz,
            evaluated: r.evaluated,
        }
    }

    /// Energy in joules (mirrors `CostEstimate::energy_j`).
    pub fn energy_j(&self) -> f64 {
        self.energy_pj * 1e-12
    }

    /// Latency in seconds.
    pub fn latency_s(&self) -> f64 {
        self.cycles / (self.clock_ghz * 1e9)
    }

    fn to_json(&self, sig: &str) -> Json {
        Json::Obj(vec![
            ("sig".into(), Json::Str(sig.to_string())),
            ("score".into(), Json::Num(self.score)),
            ("cycles".into(), Json::Num(self.cycles)),
            ("energy_pj".into(), Json::Num(self.energy_pj)),
            ("utilization".into(), Json::Num(self.utilization)),
            ("macs".into(), Json::Num(self.macs as f64)),
            ("clock_ghz".into(), Json::Num(self.clock_ghz)),
            ("evaluated".into(), Json::Num(self.evaluated as f64)),
            ("mapping".into(), mapping_to_json(&self.mapping)),
        ])
    }

    fn from_json(doc: &Json) -> Result<(String, CachedResult), String> {
        let sig = doc.str("sig").ok_or("record has no sig")?.to_string();
        let need = |k: &str| doc.num(k).ok_or_else(|| format!("record field '{k}' missing"));
        let mapping =
            mapping_from_json(doc.get("mapping").ok_or("record has no mapping")?)?;
        if mapping.levels.is_empty() {
            return Err("record mapping has no levels".into());
        }
        Ok((
            sig,
            CachedResult {
                score: need("score")?,
                cycles: need("cycles")?,
                energy_pj: need("energy_pj")?,
                utilization: need("utilization")?,
                macs: doc.u64_field("macs").ok_or("record field 'macs' missing")?,
                clock_ghz: need("clock_ghz")?,
                evaluated: doc.u64_field("evaluated").unwrap_or(0) as usize,
                mapping,
            },
        ))
    }
}

/// Tiering and flush knobs. Defaults favor a small always-correct
/// deployment: a few thousand warm results, sub-second durability.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Warm-tier entry bound.
    pub warm_entries: usize,
    /// Warm-tier approximate byte bound (serialized-record bytes).
    pub warm_bytes: usize,
    /// Flush the pending batch to disk every this many records…
    pub flush_every: usize,
    /// …or once this much time has passed with records pending
    /// (checked on insert and on [`ResultCache::flush_if_due`] ticks).
    pub flush_after: Duration,
    /// Past this file size, flush triggers compaction when less than
    /// half the file is live data (only possible when the file carried
    /// stale lines from before this process: steady-state appends are
    /// dedup'd).
    pub compact_at_bytes: u64,
}

impl Default for CacheConfig {
    fn default() -> CacheConfig {
        CacheConfig {
            warm_entries: 4096,
            warm_bytes: 32 << 20,
            flush_every: 8,
            flush_after: Duration::from_millis(200),
            compact_at_bytes: 64 << 20,
        }
    }
}

/// Cache counters, surfaced by `union client status` and the tier and
/// corruption-tolerance tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Valid record lines seen at open (before dedup).
    pub loaded: usize,
    /// Lines skipped at open (corrupt, truncated, or invalid records).
    pub skipped: usize,
    /// Records flushed to disk since open.
    pub appended: usize,
    /// Lookups answered from the warm (in-memory LRU) tier.
    pub warm_hits: u64,
    /// Lookups answered from the pending batch or by a disk read.
    pub cold_hits: u64,
    /// Lookups that found no record in any tier.
    pub misses: u64,
    /// Entries pushed out of the warm tier by its capacity bounds.
    pub warm_evictions: u64,
    /// Batched disk flushes performed.
    pub flushes: usize,
    /// Log compactions performed (open-time or size-triggered).
    pub compactions: usize,
    /// Stale lines (duplicate signatures, corrupt records, blanks)
    /// dropped by open-time compaction.
    pub compacted_dropped: usize,
}

impl crate::telemetry::MetricSource for CacheStats {
    fn metric_prefix(&self) -> &'static str {
        "cache"
    }

    fn emit_metrics(&self, out: &mut dyn FnMut(&str, f64)) {
        out("loaded", self.loaded as f64);
        out("skipped", self.skipped as f64);
        out("appended", self.appended as f64);
        out("warm_hits", self.warm_hits as f64);
        out("cold_hits", self.cold_hits as f64);
        out("misses", self.misses as f64);
        out("warm_evictions", self.warm_evictions as f64);
        out("flushes", self.flushes as f64);
        out("compactions", self.compactions as f64);
        out("compacted_dropped", self.compacted_dropped as f64);
    }
}

/// Where a known signature's record lives.
#[derive(Debug, Clone, Copy)]
enum Loc {
    /// In the pending (accepted, unflushed) batch.
    Pending,
    /// On disk: one JSONL line at `offset`, `len` bytes, no newline.
    Disk { offset: u64, len: u32 },
}

/// The tiered store. `None` path = purely in-memory (tests, or
/// `union serve` without `--cache`) — still warm-tier-bounded.
pub struct ResultCache {
    path: Option<PathBuf>,
    append: Option<File>,
    read: Option<File>,
    warm: LruCache<CachedResult>,
    /// Every signature the persistent store holds (pending or disk).
    known: HashMap<String, Loc>,
    /// Accepted-but-unflushed records, in arrival order:
    /// `(sig, record, serialized line)`.
    pending: Vec<(String, CachedResult, String)>,
    file_len: u64,
    /// Bytes of the file occupied by header + live (indexed) lines.
    live_bytes: u64,
    last_flush: Instant,
    stats: CacheStats,
    config: CacheConfig,
}

fn header_json() -> Json {
    Json::Obj(vec![("union_result_cache".into(), Json::Num(CACHE_VERSION as f64))])
}

fn open_handles(path: &Path) -> Result<(File, File), String> {
    let append = OpenOptions::new()
        .append(true)
        .open(path)
        .map_err(|e| format!("opening cache {} for append: {e}", path.display()))?;
    let read = File::open(path)
        .map_err(|e| format!("opening cache {} for read: {e}", path.display()))?;
    Ok((append, read))
}

/// Rewrite the store as header + `kept` lines (copied verbatim from
/// `text`, so surviving records stay byte-identical), via a temp file
/// and an atomic rename. Returns the rebuilt index and new file length.
fn rewrite_compacted(
    path: &Path,
    text: &str,
    kept: &[(String, usize, usize)],
) -> Result<(HashMap<String, Loc>, u64), String> {
    let header = header_json().to_line();
    let body: usize = kept.iter().map(|&(_, _, len)| len + 1).sum();
    let mut out = String::with_capacity(header.len() + 1 + body);
    out.push_str(&header);
    out.push('\n');
    let mut index = HashMap::with_capacity(kept.len());
    let mut offset = header.len() as u64 + 1;
    for (sig, start, len) in kept {
        out.push_str(&text[*start..*start + *len]);
        out.push('\n');
        index.insert(sig.clone(), Loc::Disk { offset, len: *len as u32 });
        offset += *len as u64 + 1;
    }
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "cache".into());
    let tmp = path.with_file_name(format!("{file_name}.compact-tmp"));
    std::fs::write(&tmp, &out)
        .map_err(|e| format!("writing compacted cache {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("replacing cache {}: {e}", path.display()))?;
    Ok((index, offset))
}

impl ResultCache {
    /// An in-memory cache: same dedup behavior and warm-tier bounds,
    /// nothing persisted.
    pub fn in_memory() -> ResultCache {
        ResultCache::in_memory_with(CacheConfig::default())
    }

    /// [`ResultCache::in_memory`] with explicit tier bounds.
    pub fn in_memory_with(config: CacheConfig) -> ResultCache {
        ResultCache {
            path: None,
            append: None,
            read: None,
            warm: LruCache::new(config.warm_entries, config.warm_bytes),
            known: HashMap::new(),
            pending: Vec::new(),
            file_len: 0,
            live_bytes: 0,
            last_flush: Instant::now(),
            stats: CacheStats::default(),
            config,
        }
    }

    /// Open (or create) the store at `path` with default tiering.
    pub fn open(path: &Path) -> Result<ResultCache, String> {
        ResultCache::open_with(path, CacheConfig::default())
    }

    /// Open (or create) the store at `path`, indexing every valid
    /// record (the warm tier fills lazily as records are hit).
    /// Unreadable *records* are skipped and counted (see module docs);
    /// an unreadable *file* — wrong version, missing header — is set
    /// aside as `<path>.bad-vN` and a fresh store is started. A file
    /// holding reclaimable lines (duplicates, corrupt records) is
    /// compacted in place. Only a real I/O error (permissions, missing
    /// parent directory) is fatal.
    pub fn open_with(path: &Path, config: CacheConfig) -> Result<ResultCache, String> {
        let mut stats = CacheStats::default();
        // newest record per signature, in first-appearance order:
        // (sig, line start, line len) spans into `text`
        let mut kept: Vec<(String, usize, usize)> = Vec::new();
        let mut by_sig: HashMap<String, usize> = HashMap::new();
        let mut stale = 0usize;
        let mut tail_torn = false;
        let mut have_file = false;
        let mut text = String::new();
        match std::fs::read_to_string(path) {
            Ok(t) => {
                text = t;
                // a crash mid-append can leave a half-written final
                // line with no newline; appending onto it would fuse
                // (and destroy) the next record
                tail_torn = !text.is_empty() && !text.ends_with('\n');
                let mut spans: Vec<(usize, usize)> = Vec::new();
                let mut start = 0usize;
                while start < text.len() {
                    let end = text[start..].find('\n').map_or(text.len(), |i| start + i);
                    spans.push((start, end - start));
                    start = end + 1;
                }
                let header_ok = spans
                    .first()
                    .and_then(|&(s, l)| Json::parse(&text[s..s + l]).ok())
                    .and_then(|h| h.u64_field("union_result_cache"))
                    == Some(CACHE_VERSION);
                if header_ok {
                    have_file = true;
                    for &(s, l) in &spans[1..] {
                        let line = &text[s..s + l];
                        if line.trim().is_empty() {
                            stale += 1;
                            continue;
                        }
                        match Json::parse(line).and_then(|doc| CachedResult::from_json(&doc)) {
                            Ok((sig, _)) => {
                                stats.loaded += 1;
                                match by_sig.get(&sig).copied() {
                                    // identical jobs are deterministic, so
                                    // duplicate records agree; keep the
                                    // newest, reclaim the older line
                                    Some(i) => {
                                        stale += 1;
                                        kept[i] = (sig, s, l);
                                    }
                                    None => {
                                        by_sig.insert(sig.clone(), kept.len());
                                        kept.push((sig, s, l));
                                    }
                                }
                            }
                            Err(_) => {
                                stats.skipped += 1;
                                stale += 1;
                            }
                        }
                    }
                } else if !text.trim().is_empty() {
                    // wrong version / not a cache file: set it aside
                    // rather than appending v1 records into it. The
                    // aside name keeps the full filename and never
                    // overwrites an earlier set-aside.
                    let file_name = path
                        .file_name()
                        .map(|n| n.to_string_lossy().into_owned())
                        .unwrap_or_else(|| "cache".into());
                    let mut aside = path.with_file_name(format!(
                        "{file_name}.bad-v{CACHE_VERSION}"
                    ));
                    let mut n = 1usize;
                    while aside.exists() {
                        aside = path.with_file_name(format!(
                            "{file_name}.bad-v{CACHE_VERSION}.{n}"
                        ));
                        n += 1;
                    }
                    std::fs::rename(path, &aside).map_err(|e| {
                        format!("cannot set aside incompatible cache {}: {e}", path.display())
                    })?;
                }
                // an existing-but-empty file still needs its header
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(format!("reading cache {}: {e}", path.display())),
        }

        let header = header_json().to_line();
        let mut known: HashMap<String, Loc> = HashMap::new();
        let file_len: u64;
        if !have_file {
            // fresh store: new file, empty file, or set-aside original
            let mut f = File::create(path)
                .map_err(|e| format!("creating cache {}: {e}", path.display()))?;
            writeln!(f, "{header}").map_err(|e| format!("writing cache header: {e}"))?;
            file_len = header.len() as u64 + 1;
        } else if stale > 0 {
            // open-time log compaction: drop stale lines, keep the
            // newest record per signature, byte-for-byte
            let (index, len) = rewrite_compacted(path, &text, &kept)?;
            known = index;
            file_len = len;
            stats.compactions += 1;
            stats.compacted_dropped = stale;
            crate::telemetry::event("compaction", &format!("at=open dropped={stale}"));
        } else {
            for (sig, s, l) in kept {
                known.insert(sig, Loc::Disk { offset: s as u64, len: l as u32 });
            }
            file_len = text.len() as u64 + u64::from(tail_torn);
        }
        let (mut append, read) = open_handles(path)?;
        if have_file && stale == 0 && tail_torn {
            // the torn tail was a *valid* record missing only its
            // newline (an invalid torn tail counts as stale and was
            // compacted away above): terminate it so the next append
            // does not fuse onto it
            writeln!(append).map_err(|e| format!("repairing cache tail: {e}"))?;
        }
        Ok(ResultCache {
            path: Some(path.to_path_buf()),
            append: Some(append),
            read: Some(read),
            warm: LruCache::new(config.warm_entries, config.warm_bytes),
            known,
            pending: Vec::new(),
            file_len,
            live_bytes: file_len,
            last_flush: Instant::now(),
            stats,
            config,
        })
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Counter snapshot (warm-eviction count folded in from the LRU).
    pub fn stats(&self) -> CacheStats {
        let mut s = self.stats;
        s.warm_evictions = self.warm.stats().evictions;
        s
    }

    /// Distinct signatures currently held (all tiers).
    pub fn len(&self) -> usize {
        if self.path.is_some() {
            self.known.len()
        } else {
            self.warm.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries resident in the warm tier right now.
    pub fn warm_len(&self) -> usize {
        self.warm.len()
    }

    /// Approximate warm-tier resident bytes.
    pub fn warm_bytes(&self) -> usize {
        self.warm.bytes()
    }

    /// Records accepted but not yet flushed to disk.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Is `sig` held in any tier? (No recency/counter side effects.)
    pub fn contains(&self, sig: &str) -> bool {
        self.known.contains_key(sig) || self.warm.contains(sig)
    }

    /// Look up `sig` through the tiers: warm → pending → disk. A cold
    /// hit re-parses the record's one line (bit-identical floats) and
    /// re-warms it.
    pub fn get(&mut self, sig: &str) -> Option<CachedResult> {
        if let Some(v) = self.warm.get(sig) {
            self.stats.warm_hits += 1;
            return Some(v.clone());
        }
        match self.known.get(sig).copied() {
            Some(Loc::Pending) => {
                let found = self
                    .pending
                    .iter()
                    .find(|(s, _, _)| s == sig)
                    .map(|(_, r, line)| (r.clone(), line.len() + 1));
                match found {
                    Some((r, weight)) => {
                        self.stats.cold_hits += 1;
                        self.warm.insert(sig, r.clone(), weight);
                        Some(r)
                    }
                    None => {
                        self.stats.misses += 1;
                        None
                    }
                }
            }
            Some(Loc::Disk { offset, len }) => match self.read_record(offset, len) {
                Some(r) => {
                    self.stats.cold_hits += 1;
                    self.warm.insert(sig, r.clone(), len as usize + 1);
                    Some(r)
                }
                None => {
                    // damaged on disk: forget it so a re-search can
                    // repair the entry instead of being dedup'd away
                    eprintln!("result cache: unreadable record on disk; will re-search");
                    self.known.remove(sig);
                    self.stats.misses += 1;
                    None
                }
            },
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    fn read_record(&mut self, offset: u64, len: u32) -> Option<CachedResult> {
        let f = self.read.as_mut()?;
        f.seek(SeekFrom::Start(offset)).ok()?;
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf).ok()?;
        let line = std::str::from_utf8(&buf).ok()?;
        match Json::parse(line).and_then(|doc| CachedResult::from_json(&doc)) {
            Ok((_, rec)) => Some(rec),
            Err(_) => None,
        }
    }

    /// Record a completed job: warm it, stage its line for the next
    /// batched flush, and flush if the batch/timer policy says so.
    /// Duplicate signatures are ignored (identical jobs are
    /// deterministic — the record already held answers them).
    pub fn insert(&mut self, sig: &str, result: CachedResult) {
        if self.contains(sig) {
            return;
        }
        let line = result.to_json(sig).to_line();
        let weight = line.len() + 1;
        if self.append.is_some() {
            self.known.insert(sig.to_string(), Loc::Pending);
            self.pending.push((sig.to_string(), result.clone(), line));
        }
        // warm-tier evictions are safe to drop: the record is either on
        // disk already or still in the pending batch
        let evicted_before = self.warm.stats().evictions;
        self.warm.insert(sig, result, weight);
        let evicted = self.warm.stats().evictions - evicted_before;
        if evicted > 0 {
            crate::telemetry::event("eviction", &format!("warm_evicted={evicted}"));
        }
        self.flush_if_due();
    }

    /// Flush when the batch is full or the timer has expired. The
    /// service calls this on its idle ticks so a quiet period still
    /// bounds the durability window to [`CacheConfig::flush_after`].
    pub fn flush_if_due(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        if self.pending.len() >= self.config.flush_every.max(1)
            || self.last_flush.elapsed() >= self.config.flush_after
        {
            self.flush();
        }
    }

    /// Append every pending record to disk in one write (a flush
    /// failure is reported on stderr and drops the records from the
    /// persistent index — they stay warm — rather than failing jobs).
    pub fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let Some(f) = self.append.as_mut() else {
            self.pending.clear();
            return;
        };
        let mut buf = String::new();
        for (_, _, line) in &self.pending {
            buf.push_str(line);
            buf.push('\n');
        }
        if let Err(e) = f.write_all(buf.as_bytes()).and_then(|()| f.flush()) {
            eprintln!("result cache: flush failed: {e}");
            for (sig, _, _) in std::mem::take(&mut self.pending) {
                self.known.remove(&sig);
            }
            return;
        }
        let n = self.pending.len();
        for (sig, _, line) in self.pending.drain(..) {
            self.known
                .insert(sig, Loc::Disk { offset: self.file_len, len: line.len() as u32 });
            self.file_len += line.len() as u64 + 1;
            self.live_bytes += line.len() as u64 + 1;
        }
        self.stats.appended += n;
        self.stats.flushes += 1;
        self.last_flush = Instant::now();
        if self.file_len > self.config.compact_at_bytes && self.file_len > 2 * self.live_bytes {
            self.compact();
        }
    }

    fn read_line_raw(&mut self, offset: u64, len: u32) -> Option<String> {
        let f = self.read.as_mut()?;
        f.seek(SeekFrom::Start(offset)).ok()?;
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf).ok()?;
        String::from_utf8(buf).ok()
    }

    /// Snapshot every record as its serialized JSONL line — the
    /// **cache-shipping** transfer unit (a peer answering a `sync`
    /// request streams exactly these lines). Pending records are
    /// flushed first so the snapshot equals the compaction unit:
    /// newest-record-per-signature, one line each. File-backed lines
    /// are copied **verbatim** from disk (byte-identical to what a
    /// local reopen would parse); an in-memory store serializes its
    /// warm tier without perturbing recency or the hit counters.
    pub fn export_lines(&mut self) -> Vec<String> {
        self.flush();
        if self.read.is_some() {
            let mut locs: Vec<(u64, u32)> = self
                .known
                .values()
                .filter_map(|loc| match *loc {
                    Loc::Disk { offset, len } => Some((offset, len)),
                    Loc::Pending => None, // drained by the flush above
                })
                .collect();
            locs.sort_unstable_by_key(|&(offset, _)| offset);
            locs.into_iter()
                .filter_map(|(offset, len)| self.read_line_raw(offset, len))
                .collect()
        } else {
            self.warm
                .keys_mru_first()
                .into_iter()
                .filter_map(|sig| {
                    self.warm.peek(&sig).map(|r| r.to_json(&sig).to_line())
                })
                .collect()
        }
    }

    /// Visit every resident record as `(signature, record)` — the
    /// **transfer-index mining** hook. Pending records are flushed
    /// first so one pass over the disk index covers everything; an
    /// in-memory store walks its warm tier instead. Visitation order
    /// is sorted by signature, so index construction is deterministic
    /// regardless of insertion or recency order. Unlike
    /// [`ResultCache::get`], this never perturbs warm-tier recency or
    /// the hit/miss counters (records are parsed without re-warming).
    /// Returns the number of records visited; unreadable disk records
    /// are skipped (a later `get` repairs them).
    pub fn replay_results<F: FnMut(&str, &CachedResult)>(&mut self, mut f: F) -> usize {
        let mut visited = 0usize;
        if self.append.is_some() {
            self.flush();
            let mut locs: Vec<(String, u64, u32)> = self
                .known
                .iter()
                .filter_map(|(sig, loc)| match *loc {
                    Loc::Disk { offset, len } => Some((sig.clone(), offset, len)),
                    Loc::Pending => None, // drained by the flush above
                })
                .collect();
            locs.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            for (sig, offset, len) in locs {
                if let Some(rec) = self.read_record(offset, len) {
                    f(&sig, &rec);
                    visited += 1;
                }
            }
        } else {
            let mut sigs = self.warm.keys_mru_first();
            sigs.sort_unstable();
            for sig in sigs {
                if let Some(rec) = self.warm.peek(&sig) {
                    f(&sig, rec);
                    visited += 1;
                }
            }
        }
        visited
    }

    /// Import one snapshot record received from a peer. Returns
    /// `Ok(true)` when the record was new, `Ok(false)` when the
    /// signature was already held (identical jobs are deterministic, so
    /// the resident record already answers it), `Err` when the document
    /// is not a valid cache record — the sync client *skips and counts*
    /// such records, mirroring the corruption tolerance of
    /// [`ResultCache::open`].
    pub fn import_record(&mut self, doc: &Json) -> Result<bool, String> {
        let (sig, record) = CachedResult::from_json(doc)?;
        if self.contains(&sig) {
            return Ok(false);
        }
        self.insert(&sig, record);
        Ok(true)
    }

    /// [`ResultCache::import_record`] from a raw JSONL line.
    pub fn import_line(&mut self, line: &str) -> Result<bool, String> {
        self.import_record(&Json::parse(line.trim())?)
    }

    /// Size-triggered/explicit log compaction: flush, then rewrite the
    /// file keeping only live (indexed) lines, verbatim.
    pub fn compact(&mut self) {
        self.flush();
        let Some(path) = self.path.clone() else { return };
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("result cache: compaction read failed: {e}");
                return;
            }
        };
        let mut kept: Vec<(String, usize, usize)> = self
            .known
            .iter()
            .filter_map(|(sig, loc)| match *loc {
                Loc::Disk { offset, len } => {
                    Some((sig.clone(), offset as usize, len as usize))
                }
                Loc::Pending => None, // drained by the flush above
            })
            .collect();
        kept.sort_by_key(|&(_, start, _)| start);
        match rewrite_compacted(&path, &text, &kept) {
            Ok((index, len)) => match open_handles(&path) {
                Ok((append, read)) => {
                    let reclaimed = self.file_len.saturating_sub(len);
                    self.append = Some(append);
                    self.read = Some(read);
                    self.known = index;
                    self.file_len = len;
                    self.live_bytes = len;
                    self.stats.compactions += 1;
                    crate::telemetry::event(
                        "compaction",
                        &format!("at=flush reclaimed_bytes={reclaimed}"),
                    );
                }
                Err(e) => eprintln!("result cache: reopen after compaction failed: {e}"),
            },
            Err(e) => eprintln!("result cache: compaction failed: {e}"),
        }
    }
}

impl Drop for ResultCache {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::LevelMapping;

    fn sample_result(seed: u64) -> CachedResult {
        CachedResult {
            score: 1.0 / (seed as f64 + 3.0),
            mapping: Mapping {
                levels: vec![LevelMapping {
                    temporal_order: vec![0, 1],
                    temporal_tile: vec![seed + 1, 4],
                    spatial_tile: vec![1, 4],
                }],
            },
            cycles: 123.5 * seed as f64,
            energy_pj: 9.75e4,
            utilization: 0.5,
            macs: 1 << 20,
            clock_ghz: 1.0,
            evaluated: 600,
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        static NEXT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "union-cache-test-{}-{tag}-{n}.jsonl",
            std::process::id()
        ))
    }

    fn bits(r: &CachedResult) -> [u64; 5] {
        [
            r.score.to_bits(),
            r.cycles.to_bits(),
            r.energy_pj.to_bits(),
            r.utilization.to_bits(),
            r.clock_ghz.to_bits(),
        ]
    }

    #[test]
    fn record_roundtrip_is_bit_identical() {
        let r = sample_result(7);
        let line = r.to_json("sig|x").to_line();
        let (sig, back) = CachedResult::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(sig, "sig|x");
        assert_eq!(back.score.to_bits(), r.score.to_bits());
        assert_eq!(back.cycles.to_bits(), r.cycles.to_bits());
        assert_eq!(back, r);
    }

    #[test]
    fn persists_across_reopen_via_cold_tier() {
        let path = tmp_path("reopen");
        {
            let mut c = ResultCache::open(&path).unwrap();
            c.insert("a", sample_result(1));
            c.insert("b", sample_result(2));
            c.flush();
            assert_eq!(c.stats().appended, 2);
        }
        let mut c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().loaded, 2);
        assert_eq!(c.stats().skipped, 0);
        assert_eq!(c.stats().compactions, 0, "a clean file is not rewritten");
        assert_eq!(c.warm_len(), 0, "warm tier fills lazily");
        let a = c.get("a").expect("cold hit");
        assert_eq!(a, sample_result(1));
        assert_eq!(bits(&a), bits(&sample_result(1)), "cold read is bit-identical");
        assert_eq!(c.stats().cold_hits, 1);
        assert_eq!(c.get("a").unwrap(), a, "second lookup is warm");
        assert_eq!(c.stats().warm_hits, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flush_batches_by_count_and_explicitly() {
        let path = tmp_path("batch");
        let config = CacheConfig {
            flush_every: 3,
            flush_after: Duration::from_secs(3600),
            ..CacheConfig::default()
        };
        let mut c = ResultCache::open_with(&path, config).unwrap();
        c.insert("a", sample_result(1));
        c.insert("b", sample_result(2));
        assert_eq!(c.stats().appended, 0, "below the batch size: nothing flushed");
        assert_eq!(c.pending_len(), 2);
        assert_eq!(c.get("a").unwrap(), sample_result(1), "pending records still hit");
        c.insert("c", sample_result(3));
        assert_eq!(c.stats().appended, 3, "batch size reached: one flush");
        assert_eq!(c.stats().flushes, 1);
        assert_eq!(c.pending_len(), 0);
        c.insert("d", sample_result(4));
        c.flush();
        assert_eq!(c.stats().appended, 4);
        drop(c);
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crash_window_loses_at_most_the_unflushed_tail() {
        let path = tmp_path("crash");
        let config = CacheConfig {
            flush_every: 100,
            flush_after: Duration::from_secs(3600),
            ..CacheConfig::default()
        };
        let mut c = ResultCache::open_with(&path, config).unwrap();
        c.insert("a", sample_result(1));
        c.insert("b", sample_result(2));
        c.flush();
        c.insert("c", sample_result(3));
        c.insert("d", sample_result(4));
        assert_eq!(c.len(), 4);
        // simulate a crash: no Drop, so the pending batch never lands
        std::mem::forget(c);
        let mut back = ResultCache::open(&path).unwrap();
        assert_eq!(back.len(), 2, "exactly the unflushed tail is lost");
        assert_eq!(back.stats().skipped, 0, "no torn lines from the crash");
        assert!(back.get("a").is_some() && back.get("b").is_some());
        assert!(back.get("c").is_none() && back.get("d").is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compaction_keeps_answers_byte_identical() {
        let path = tmp_path("compact");
        let (pre_a, pre_b) = {
            let mut c = ResultCache::open(&path).unwrap();
            c.insert("a", sample_result(1));
            c.insert("b", sample_result(2));
            c.flush();
            (c.get("a").unwrap(), c.get("b").unwrap())
        };
        // another process appends a duplicate record for "a" (identical
        // jobs are deterministic, so duplicate lines agree)
        let text = std::fs::read_to_string(&path).unwrap();
        let a_line = text.lines().find(|l| l.contains("\"sig\":\"a\"")).unwrap().to_string();
        std::fs::write(&path, format!("{text}{a_line}\n")).unwrap();

        let mut c = ResultCache::open(&path).unwrap();
        assert_eq!(c.stats().loaded, 3, "all valid lines counted");
        assert_eq!(c.stats().compactions, 1, "duplicate triggers open-time compaction");
        assert_eq!(c.stats().compacted_dropped, 1);
        assert_eq!(c.len(), 2);
        let post_a = c.get("a").unwrap();
        let post_b = c.get("b").unwrap();
        assert_eq!(bits(&post_a), bits(&pre_a));
        assert_eq!(bits(&post_b), bits(&pre_b));
        assert_eq!((post_a, post_b), (pre_a, pre_b), "answers unchanged by compaction");
        drop(c);
        let compacted = std::fs::read_to_string(&path).unwrap();
        assert_eq!(compacted.matches("\"sig\":\"a\"").count(), 1, "one record per sig");
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.stats().compactions, 0, "compaction converges: no rewrite loop");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compaction_of_corrupt_file_keeps_skip_and_count() {
        let path = tmp_path("corrupt");
        {
            let mut c = ResultCache::open(&path).unwrap();
            c.insert("a", sample_result(1));
            c.insert("b", sample_result(2));
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("not json at all\n");
        text.push_str("{\"sig\":\"orphan\",\"score\":1.5}\n");
        text.push_str("{\"sig\":\"torn\",\"score\":2.5,\"mapping\":[[[0],[1");
        std::fs::write(&path, &text).unwrap();

        let mut c = ResultCache::open(&path).unwrap();
        assert_eq!(c.stats().skipped, 3, "all three bad lines skipped and counted");
        assert_eq!(c.stats().compactions, 1, "bad lines are reclaimed");
        assert_eq!(c.len(), 2, "both good records survive");
        assert_eq!(c.get("a").unwrap(), sample_result(1));
        // the store still accepts appends after the rewrite
        c.insert("c", sample_result(3));
        c.flush();
        drop(c);
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().skipped, 0, "compacted file is clean");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_mismatch_sets_file_aside() {
        let path = tmp_path("badver");
        let bad = "{\"union_result_cache\":99}\n{\"sig\":\"x\"}\n";
        std::fs::write(&path, bad).unwrap();
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 0);
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let aside = path.with_file_name(format!("{name}.bad-v1"));
        assert!(aside.exists(), "old file preserved (full filename kept)");
        // a second incompatible file must not overwrite the first aside
        drop(c);
        std::fs::write(&path, bad).unwrap();
        let _ = ResultCache::open(&path).unwrap();
        let aside2 = path.with_file_name(format!("{name}.bad-v1.1"));
        assert!(aside.exists() && aside2.exists(), "both asides preserved");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&aside).ok();
        std::fs::remove_file(&aside2).ok();
    }

    #[test]
    fn warm_tier_is_bounded_and_backed_by_disk() {
        let path = tmp_path("tiered");
        let config = CacheConfig { warm_entries: 2, flush_every: 1, ..CacheConfig::default() };
        let mut c = ResultCache::open_with(&path, config).unwrap();
        for (i, sig) in ["a", "b", "c", "d"].iter().enumerate() {
            c.insert(sig, sample_result(i as u64));
        }
        assert_eq!(c.warm_len(), 2, "warm tier respects its entry bound");
        assert_eq!(c.len(), 4, "every record is still held");
        assert!(c.stats().warm_evictions >= 2);
        // evicted entries come back from disk, bit-identical
        let a = c.get("a").expect("disk-backed hit after eviction");
        assert_eq!(bits(&a), bits(&sample_result(0)));
        assert!(c.stats().cold_hits >= 1);
        assert_eq!(c.warm_len(), 2, "re-warming keeps the bound");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn explicit_compact_reclaims_nothing_on_a_clean_store() {
        let path = tmp_path("noop");
        let mut c = ResultCache::open(&path).unwrap();
        c.insert("a", sample_result(1));
        let before_len = c.len();
        c.compact();
        assert_eq!(c.stats().compactions, 1);
        assert_eq!(c.len(), before_len);
        assert_eq!(c.get("a").unwrap(), sample_result(1), "records survive the rewrite");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn export_import_roundtrip_is_bit_identical() {
        let src_path = tmp_path("export-src");
        let dst_path = tmp_path("export-dst");
        let mut src = ResultCache::open(&src_path).unwrap();
        src.insert("a", sample_result(1));
        src.insert("b", sample_result(2));
        let lines = src.export_lines();
        assert_eq!(lines.len(), 2, "export flushes pending records first");
        // exported lines are the verbatim on-disk lines
        let text = std::fs::read_to_string(&src_path).unwrap();
        for line in &lines {
            assert!(text.contains(line.as_str()), "exported line not verbatim: {line}");
        }

        let mut dst = ResultCache::open(&dst_path).unwrap();
        for line in &lines {
            assert_eq!(dst.import_line(line), Ok(true), "fresh record imports");
        }
        for line in &lines {
            assert_eq!(dst.import_line(line), Ok(false), "duplicate import is a no-op");
        }
        dst.flush();
        assert_eq!(dst.len(), 2);
        let a = dst.get("a").unwrap();
        assert_eq!(bits(&a), bits(&sample_result(1)), "imported record is bit-identical");
        // a re-export of the destination ships the identical lines
        let mut re = dst.export_lines();
        let mut orig = lines.clone();
        re.sort();
        orig.sort();
        assert_eq!(re, orig, "import → export is byte-stable");
        std::fs::remove_file(&src_path).ok();
        std::fs::remove_file(&dst_path).ok();
    }

    #[test]
    fn import_of_bad_records_errs_without_panicking() {
        let mut c = ResultCache::in_memory();
        assert!(c.import_line("not json at all").is_err());
        assert!(c.import_line("{\"sig\":\"orphan\",\"score\":1.5}").is_err());
        assert!(c.import_line("{\"score\":1.5}").is_err(), "record without sig");
        assert_eq!(c.len(), 0, "failed imports leave the store untouched");
        // a good record still imports after the failures
        let line = sample_result(3).to_json("ok").to_line();
        assert_eq!(c.import_line(&line), Ok(true));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn in_memory_export_does_not_perturb_warm_stats() {
        let mut c = ResultCache::in_memory();
        c.insert("a", sample_result(1));
        c.insert("b", sample_result(2));
        let before = c.stats();
        let lines = c.export_lines();
        assert_eq!(lines.len(), 2);
        let after = c.stats();
        assert_eq!((before.warm_hits, before.misses), (after.warm_hits, after.misses));
    }

    #[test]
    fn replay_visits_every_record_sorted_without_stat_churn() {
        let path = tmp_path("replay");
        let mut c = ResultCache::open(&path).unwrap();
        c.insert("b", sample_result(2));
        c.insert("a", sample_result(1));
        c.insert("c", sample_result(3)); // left pending: replay flushes first
        let before = c.stats();
        let mut seen = Vec::new();
        let n = c.replay_results(|sig, rec| seen.push((sig.to_string(), rec.clone())));
        assert_eq!(n, 3);
        assert_eq!(
            seen.iter().map(|(s, _)| s.as_str()).collect::<Vec<_>>(),
            ["a", "b", "c"],
            "visitation is signature-sorted"
        );
        assert_eq!(seen[0].1, sample_result(1));
        let after = c.stats();
        assert_eq!(
            (before.warm_hits, before.cold_hits, before.misses),
            (after.warm_hits, after.cold_hits, after.misses),
            "replay does not count as lookups"
        );
        assert_eq!(c.warm_len(), 3, "replay leaves the warm tier as-is");
        drop(c);

        // in-memory stores replay their warm tier, same order guarantee
        let mut m = ResultCache::in_memory();
        m.insert("z", sample_result(9));
        m.insert("y", sample_result(8));
        let mut order = Vec::new();
        assert_eq!(m.replay_results(|sig, _| order.push(sig.to_string())), 2);
        assert_eq!(order, ["y", "z"]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn in_memory_cache_never_touches_disk() {
        let mut c = ResultCache::in_memory();
        c.insert("a", sample_result(1));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().appended, 0);
        assert!(c.path().is_none());
        assert_eq!(c.get("a").unwrap(), sample_result(1));
        assert_eq!(c.stats().warm_hits, 1);
    }
}
