//! The **JSON-lines wire protocol** of the mapping service.
//!
//! One request per line, one (or more, for scripting convenience)
//! response lines back — the same framing over TCP and over
//! stdin/stdout, so `union serve --stdio` is scriptable with a heredoc
//! and the TCP path needs no extra framing layer. The JSON codec is a
//! from-scratch recursive-descent parser/printer (the offline build has
//! no serde), shared with the persistent result cache, whose records
//! are the same [`Json`] documents appended to a file.
//!
//! ## Requests
//!
//! ```text
//! {"type":"search","id":"r1","workload":"gemm:64x64x64","arch":"edge",
//!  "cost":"analytical","objective":"edp","effort":"fast","seed":42}
//! {"type":"evaluate","workload":"gemm:8x8x8","arch":"fig5","mapping":[...]}
//! {"type":"status"}
//! {"type":"metrics"}
//! {"type":"trace","since":120,"limit":64}
//! {"type":"shutdown"}
//! {"type":"sync"}
//! ```
//!
//! `search` fields beyond `workload` are optional (defaults in
//! brackets): `arch` [`edge`], `cost` (`analytical`, `maestro`, or
//! `sparse-analytical:d=D[,meta=M]`) [`analytical`], `objective`
//! [`edp`], `effort` (`fast`, `thorough` or a sample count) [`fast`],
//! `seed` [42], `constraints` (inline `.ucon` text) [none], `id` (any
//! string, echoed back) [absent], `progress` (stream anytime
//! `{"type":"progress",...}` events before the final result) [false].
//!
//! ## Responses
//!
//! Every response carries `"type"` and `"ok"`. A `search` answer is a
//! `result` (score + summary metrics + the mapping as a nested array,
//! losslessly decodable via [`mapping_from_json`]), a `status` answer
//! mirrors the broker counters — including the `transfer_*` family
//! (index size, lookups, hits, seeded jobs, seed wins) that tracks the
//! cache-mined warm-start path — and errors/backpressure come back as
//! `error` / `overloaded` lines tied to the request `id`. A `sync`
//! answer is the one multi-line response: a `sync` header, then raw
//! cache-record lines (which carry `"sig"` rather than `"type"` —
//! they are the on-disk snapshot verbatim), then a `sync_end` trailer
//! (see `docs/PROTOCOL.md`).
//!
//! Floating-point numbers are printed with Rust's shortest round-trip
//! formatting, so a score that travels through the wire (or the
//! on-disk cache) parses back to the **bit-identical** `f64` — the
//! foundation of the "cached result == searched result" guarantee.

use crate::mappers::Objective;
use crate::mapping::{LevelMapping, Mapping};

/// A parsed JSON value. Objects preserve insertion order (we never need
/// map semantics beyond key lookup, and ordered output keeps responses
/// and cache records diffable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key)? {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn num(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn u64_field(&self, key: &str) -> Option<u64> {
        let n = self.num(key)?;
        if n.is_finite() && n >= 0.0 {
            Some(n as u64)
        } else {
            None
        }
    }

    pub fn bool_field(&self, key: &str) -> Option<bool> {
        match self.get(key)? {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn arr(&self, key: &str) -> Option<&[Json]> {
        match self.get(key)? {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a single line (no pretty-printing: the protocol is
    /// line-framed).
    pub fn to_line(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // shortest round-trip formatting: parses back to the
                    // bit-identical f64
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document from `src` (trailing whitespace allowed,
    /// trailing garbage is an error — cache records and protocol lines
    /// are exactly one document each).
    pub fn parse(src: &str) -> Result<Json, String> {
        let bytes = src.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos).copied() {
        None => Err("unexpected end of input".into()),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos).copied() {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos).copied() {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad utf8 in number")?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number '{text}' at byte {start}"))
}

/// Read the 4 hex digits of a `\uXXXX` escape; `u_pos` points at the `u`.
fn parse_u_escape(b: &[u8], u_pos: usize) -> Result<u32, String> {
    let hex = b.get(u_pos + 1..u_pos + 5).ok_or("truncated \\u escape")?;
    let hex = std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?;
    u32::from_str_radix(hex, 16).map_err(|_| format!("bad \\u escape '{hex}'"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}"));
    }
    *pos += 1;
    let mut out = String::new();
    let mut chunk_start = *pos;
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                out.push_str(
                    std::str::from_utf8(&b[chunk_start..*pos]).map_err(|_| "bad utf8")?,
                );
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                out.push_str(
                    std::str::from_utf8(&b[chunk_start..*pos]).map_err(|_| "bad utf8")?,
                );
                *pos += 1;
                match b.get(*pos).copied() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let code = parse_u_escape(b, *pos)?;
                        *pos += 4;
                        if (0xD800..0xDC00).contains(&code) {
                            // high surrogate: standard encoders emit
                            // non-BMP characters as \uD8xx\uDCxx pairs —
                            // combine with the mandatory low half
                            if b.get(*pos + 1..*pos + 3) == Some(&b"\\u"[..]) {
                                let low = parse_u_escape(b, *pos + 2)?;
                                if (0xDC00..0xE000).contains(&low) {
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                    *pos += 6;
                                } else {
                                    out.push('\u{FFFD}'); // unpaired high
                                }
                            } else {
                                out.push('\u{FFFD}'); // unpaired high
                            }
                        } else if (0xDC00..0xE000).contains(&code) {
                            out.push('\u{FFFD}'); // stray low surrogate
                        } else {
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
                chunk_start = *pos;
            }
            _ => *pos += 1,
        }
    }
    Err("unterminated string".into())
}

// ---------------------------------------------------------------------------
// mapping <-> JSON
// ---------------------------------------------------------------------------

/// Encode a mapping as a nested array: one `[temporal_order,
/// temporal_tile, spatial_tile]` triple per cluster level, outermost
/// first. Lossless — see [`mapping_from_json`].
pub fn mapping_to_json(m: &Mapping) -> Json {
    Json::Arr(
        m.levels
            .iter()
            .map(|l| {
                Json::Arr(vec![
                    Json::Arr(l.temporal_order.iter().map(|&d| Json::Num(d as f64)).collect()),
                    Json::Arr(l.temporal_tile.iter().map(|&t| Json::Num(t as f64)).collect()),
                    Json::Arr(l.spatial_tile.iter().map(|&t| Json::Num(t as f64)).collect()),
                ])
            })
            .collect(),
    )
}

/// Decode a mapping produced by [`mapping_to_json`].
pub fn mapping_from_json(j: &Json) -> Result<Mapping, String> {
    let levels = match j {
        Json::Arr(levels) => levels,
        _ => return Err("mapping must be an array of levels".into()),
    };
    let mut out = Vec::with_capacity(levels.len());
    for (i, level) in levels.iter().enumerate() {
        let triple = match level {
            Json::Arr(t) if t.len() == 3 => t,
            _ => return Err(format!("mapping level {i} must be [order, tt, st]")),
        };
        let ints = |j: &Json, what: &str| -> Result<Vec<u64>, String> {
            match j {
                Json::Arr(v) => v
                    .iter()
                    .map(|x| match x {
                        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as u64),
                        _ => Err(format!("level {i} {what}: not a non-negative integer")),
                    })
                    .collect(),
                _ => Err(format!("level {i} {what} must be an array")),
            }
        };
        out.push(LevelMapping {
            temporal_order: ints(&triple[0], "order")?.iter().map(|&d| d as usize).collect(),
            temporal_tile: ints(&triple[1], "temporal_tile")?,
            spatial_tile: ints(&triple[2], "spatial_tile")?,
        });
    }
    Ok(Mapping { levels: out })
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

/// A `search`/`evaluate` job description as it appears on the wire —
/// spec *strings*, not parsed objects; the server resolves them with
/// the same parsers the CLI uses, so a job means exactly the same
/// thing whether it arrives over TCP or on `union network`'s command
/// line.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Workload spec (`gemm:MxNxK`, `conv:...`, a Table IV name, ...).
    pub workload: String,
    /// Arch spec (`edge`, `cloud:32x64`, a `.uarch` path, ...).
    pub arch: String,
    /// Cost-model spec (`analytical` | `maestro` |
    /// `sparse-analytical:d=D[,meta=M]`); one grammar with the CLI's
    /// `--cost` flag, parsed by [`crate::cost::CostKind::parse`].
    pub cost: String,
    pub objective: Objective,
    /// Per-job candidate budget (already resolved from `effort`).
    pub samples: usize,
    pub seed: u64,
    /// Inline `.ucon` constraints text; empty = unconstrained.
    pub constraints: String,
}

/// A parsed protocol request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Search {
        id: Option<String>,
        spec: JobSpec,
        /// Opt into anytime streaming: the server interleaves
        /// `{"type":"progress",...}` events (current incumbent score +
        /// candidates evaluated) before the final `result` line. Off by
        /// default — a `progress`-blind client that skips unknown event
        /// types keeps working either way.
        progress: bool,
    },
    Evaluate { id: Option<String>, spec: JobSpec, mapping: Json },
    Status { id: Option<String> },
    Shutdown { id: Option<String> },
    /// Stream the peer's cache snapshot (cache shipping): the server
    /// answers with a `{"type":"sync",...}` header carrying the cache
    /// version and record count, then one raw cache-record line per
    /// held signature, then a `{"type":"sync_end",...}` trailer. A
    /// new or recovered cluster member imports the stream to warm from
    /// a neighbor instead of re-searching.
    Sync { id: Option<String> },
    /// Scrape the process telemetry: the full metrics registry
    /// (counters, gauges, histograms) plus every service
    /// `MetricSource`, as one JSON document that also embeds a
    /// Prometheus-style text rendering (see `docs/PROTOCOL.md`).
    Metrics { id: Option<String> },
    /// Dump the flight recorder: the newest `limit` [default 256]
    /// events with sequence number `> since` [default 0], oldest
    /// first. `union trace --follow` polls this with its last-seen
    /// sequence number.
    Trace { id: Option<String>, since: Option<u64>, limit: Option<usize> },
}

impl Request {
    /// The echoed request id, if any.
    pub fn id(&self) -> Option<&str> {
        match self {
            Request::Search { id, .. }
            | Request::Evaluate { id, .. }
            | Request::Status { id }
            | Request::Shutdown { id }
            | Request::Sync { id }
            | Request::Metrics { id }
            | Request::Trace { id, .. } => id.as_deref(),
        }
    }

    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request, String> {
        let doc = Json::parse(line)?;
        let id = doc.str("id").map(|s| s.to_string());
        let typ = doc.str("type").ok_or("request needs a \"type\" field")?;
        match typ {
            "status" => Ok(Request::Status { id }),
            "shutdown" => Ok(Request::Shutdown { id }),
            "sync" => Ok(Request::Sync { id }),
            "metrics" => Ok(Request::Metrics { id }),
            "trace" => Ok(Request::Trace {
                id,
                since: doc.u64_field("since"),
                limit: doc.u64_field("limit").map(|n| n as usize),
            }),
            "search" => Ok(Request::Search {
                id,
                spec: job_spec(&doc)?,
                progress: doc.bool_field("progress").unwrap_or(false),
            }),
            "evaluate" => {
                let mapping = doc
                    .get("mapping")
                    .ok_or("evaluate needs a \"mapping\" field")?
                    .clone();
                Ok(Request::Evaluate { id, spec: job_spec(&doc)?, mapping })
            }
            other => Err(format!(
                "unknown request type '{other}' \
                 (search, evaluate, status, metrics, trace, shutdown, sync)"
            )),
        }
    }

    /// Serialize back to a request line (the client side of the
    /// protocol; also keeps round-trip tests honest).
    pub fn to_line(&self) -> String {
        let mut fields: Vec<(String, Json)> = Vec::new();
        let push_id = |fields: &mut Vec<(String, Json)>, id: &Option<String>| {
            if let Some(id) = id {
                fields.push(("id".into(), Json::Str(id.clone())));
            }
        };
        match self {
            Request::Status { id } => {
                fields.push(("type".into(), Json::Str("status".into())));
                push_id(&mut fields, id);
            }
            Request::Shutdown { id } => {
                fields.push(("type".into(), Json::Str("shutdown".into())));
                push_id(&mut fields, id);
            }
            Request::Sync { id } => {
                fields.push(("type".into(), Json::Str("sync".into())));
                push_id(&mut fields, id);
            }
            Request::Metrics { id } => {
                fields.push(("type".into(), Json::Str("metrics".into())));
                push_id(&mut fields, id);
            }
            Request::Trace { id, since, limit } => {
                fields.push(("type".into(), Json::Str("trace".into())));
                push_id(&mut fields, id);
                if let Some(s) = since {
                    fields.push(("since".into(), Json::Num(*s as f64)));
                }
                if let Some(l) = limit {
                    fields.push(("limit".into(), Json::Num(*l as f64)));
                }
            }
            Request::Search { id, spec, progress } => {
                fields.push(("type".into(), Json::Str("search".into())));
                push_id(&mut fields, id);
                push_spec(&mut fields, spec);
                if *progress {
                    fields.push(("progress".into(), Json::Bool(true)));
                }
            }
            Request::Evaluate { id, spec, mapping } => {
                fields.push(("type".into(), Json::Str("evaluate".into())));
                push_id(&mut fields, id);
                push_spec(&mut fields, spec);
                fields.push(("mapping".into(), mapping.clone()));
            }
        }
        Json::Obj(fields).to_line()
    }
}

fn push_spec(fields: &mut Vec<(String, Json)>, spec: &JobSpec) {
    fields.push(("workload".into(), Json::Str(spec.workload.clone())));
    fields.push(("arch".into(), Json::Str(spec.arch.clone())));
    fields.push(("cost".into(), Json::Str(spec.cost.clone())));
    fields.push(("objective".into(), Json::Str(objective_flag(spec.objective).into())));
    fields.push(("samples".into(), Json::Num(spec.samples as f64)));
    fields.push(("seed".into(), Json::Num(spec.seed as f64)));
    if !spec.constraints.is_empty() {
        fields.push(("constraints".into(), Json::Str(spec.constraints.clone())));
    }
}

/// Parse the `edp|energy|latency` objective spelling shared by the CLI
/// and the protocol.
pub fn parse_objective(s: &str) -> Result<Objective, String> {
    match s {
        "edp" => Ok(Objective::Edp),
        "energy" => Ok(Objective::Energy),
        "latency" => Ok(Objective::Latency),
        other => Err(format!("unknown objective '{other}' (edp, energy, latency)")),
    }
}

/// The wire spelling of an objective (inverse of [`parse_objective`]).
pub fn objective_flag(o: Objective) -> &'static str {
    match o {
        Objective::Edp => "edp",
        Objective::Energy => "energy",
        Objective::Latency => "latency",
    }
}

fn job_spec(doc: &Json) -> Result<JobSpec, String> {
    let workload = doc
        .str("workload")
        .ok_or("search/evaluate needs a \"workload\" field")?
        .to_string();
    let objective = parse_objective(doc.str("objective").unwrap_or("edp"))?;
    // `samples` (explicit integer) wins over `effort` (fast|thorough|N)
    let samples = match doc.u64_field("samples") {
        Some(n) if n > 0 => n as usize,
        _ => crate::experiments::Effort::from_flag(doc.str("effort").unwrap_or("fast"))?
            .samples(),
    };
    Ok(JobSpec {
        workload,
        arch: doc.str("arch").unwrap_or("edge").to_string(),
        cost: doc.str("cost").unwrap_or("analytical").to_string(),
        objective,
        samples,
        seed: doc.u64_field("seed").unwrap_or(42),
        constraints: doc.str("constraints").unwrap_or("").to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrips() {
        let cases = [
            "null",
            "true",
            "[1,2.5,-3e-2]",
            "\"a\\\"b\\\\c\\nd\"",
            "{\"a\":[{\"b\":null}],\"c\":\"x\"}",
            "{}",
            "[]",
        ];
        for src in cases {
            let v = Json::parse(src).unwrap();
            let printed = v.to_line();
            assert_eq!(Json::parse(&printed).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn surrogate_pairs_decode() {
        // standard encoders (python json, serde_json, jq) emit non-BMP
        // characters as \uD8xx\uDCxx pairs — they must combine
        let v = Json::parse("\"\\ud83d\\ude00 ok\"").unwrap();
        assert_eq!(v, Json::Str("\u{1F600} ok".into()));
        // unpaired halves degrade to the replacement char, not an error
        assert_eq!(
            Json::parse("\"\\ud83d x\"").unwrap(),
            Json::Str("\u{FFFD} x".into())
        );
        assert_eq!(Json::parse("\"\\ude00\"").unwrap(), Json::Str("\u{FFFD}".into()));
    }

    #[test]
    fn json_rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "nul", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn f64_roundtrip_is_bit_identical() {
        for v in [1.0 / 3.0, 2.36e-7, f64::MIN_POSITIVE, 1e300, -0.0, 123456.789] {
            let line = Json::Num(v).to_line();
            match Json::parse(&line).unwrap() {
                Json::Num(back) => assert_eq!(back.to_bits(), v.to_bits(), "{line}"),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn request_roundtrips() {
        let spec = JobSpec {
            workload: "gemm:64x64x64".into(),
            arch: "edge".into(),
            cost: "analytical".into(),
            objective: Objective::Edp,
            samples: 600,
            seed: 42,
            constraints: "parallel_dims: [M, K]\n".into(),
        };
        for req in [
            Request::Status { id: Some("s1".into()) },
            Request::Shutdown { id: None },
            Request::Sync { id: Some("y1".into()) },
            Request::Sync { id: None },
            Request::Metrics { id: Some("m1".into()) },
            Request::Metrics { id: None },
            Request::Trace { id: Some("t1".into()), since: Some(120), limit: Some(64) },
            Request::Trace { id: None, since: None, limit: None },
            Request::Search { id: Some("r1".into()), spec: spec.clone(), progress: false },
            Request::Search { id: Some("r2".into()), spec: spec.clone(), progress: true },
        ] {
            let line = req.to_line();
            assert_eq!(Request::parse(&line).unwrap(), req, "{line}");
        }
    }

    #[test]
    fn request_defaults_apply() {
        let r = Request::parse("{\"type\":\"search\",\"workload\":\"gemm:8x8x8\"}").unwrap();
        match r {
            Request::Search { id, spec, progress } => {
                assert_eq!(id, None);
                assert!(!progress, "streaming is strictly opt-in");
                assert_eq!(spec.arch, "edge");
                assert_eq!(spec.cost, "analytical");
                assert_eq!(spec.objective, Objective::Edp);
                assert_eq!(spec.seed, 42);
                assert!(spec.samples > 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn request_errors_are_clear() {
        assert!(Request::parse("{\"type\":\"search\"}")
            .unwrap_err()
            .contains("workload"));
        assert!(Request::parse("{\"workload\":\"x\"}").unwrap_err().contains("type"));
        assert!(Request::parse("{\"type\":\"warp\"}").unwrap_err().contains("warp"));
        assert!(Request::parse("{\"type\":\"evaluate\",\"workload\":\"x\"}")
            .unwrap_err()
            .contains("mapping"));
    }

    #[test]
    fn mapping_json_roundtrips() {
        let m = Mapping {
            levels: vec![
                LevelMapping {
                    temporal_order: vec![0, 2, 1],
                    temporal_tile: vec![4096, 16, 16],
                    spatial_tile: vec![4096, 16, 16],
                },
                LevelMapping {
                    temporal_order: vec![2, 0, 1],
                    temporal_tile: vec![1, 1, 1],
                    spatial_tile: vec![1, 1, 1],
                },
            ],
        };
        let j = mapping_to_json(&m);
        let back = mapping_from_json(&Json::parse(&j.to_line()).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn mapping_json_rejects_malformed() {
        for bad in ["{}", "[[1,2]]", "[[[0],[1],[-1]]]", "[[[0],[1.5],[1]]]"] {
            let j = Json::parse(bad).unwrap();
            assert!(mapping_from_json(&j).is_err(), "{bad}");
        }
    }
}
