//! Reporting layer: tables, CSV emission, normalization and ASCII bar
//! charts used by the figure-regeneration benches and the examples.

use std::fmt::Write as _;

/// A rectangular table with a header row, an optional rollup (totals)
/// row rendered under a separator, and optional row grouping (a blank
/// line whenever the value in the group column changes) — the shape the
/// network-level per-layer reports use.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Totals row rendered after the body under a separator.
    pub rollup: Option<Vec<String>>,
    /// When set, `render` separates runs of rows whose value in this
    /// column differs (grouped report).
    pub group_col: Option<usize>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            rollup: None,
            group_col: None,
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Set the rollup (totals) row.
    pub fn set_rollup(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "rollup width mismatch");
        self.rollup = Some(cells);
    }

    /// Group rows by a column: `render` inserts a blank line between
    /// consecutive rows whose values in `col` differ.
    pub fn group_by(&mut self, col: usize) {
        assert!(col < self.header.len(), "group column out of range");
        self.group_col = Some(col);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in self.rows.iter().chain(&self.rollup) {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let separator = widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>()
            .join("  ");
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let _ = writeln!(out, "{separator}");
        let mut prev_group: Option<&str> = None;
        for row in &self.rows {
            if let Some(col) = self.group_col {
                if prev_group.is_some_and(|p| p != row[col]) {
                    let _ = writeln!(out);
                }
                prev_group = Some(&row[col]);
            }
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        if let Some(rollup) = &self.rollup {
            let _ = writeln!(out, "{separator}");
            let _ = writeln!(out, "{}", fmt_row(rollup, &widths));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    /// The rollup row, if any, is the last record.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in self.rows.iter().chain(&self.rollup) {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// Normalize a series to its minimum (the paper plots normalized EDP).
pub fn normalize_to_min(values: &[f64]) -> Vec<f64> {
    let min = values
        .iter()
        .copied()
        .filter(|v| v.is_finite() && *v > 0.0)
        .fold(f64::INFINITY, f64::min);
    if !min.is_finite() {
        return values.to_vec();
    }
    values.iter().map(|v| v / min).collect()
}

/// An ASCII horizontal bar chart on a log scale (for figure benches).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let mut out = String::new();
    let _ = writeln!(out, "-- {title} --");
    let finite: Vec<f64> = values.iter().copied().filter(|v| v.is_finite() && *v > 0.0).collect();
    if finite.is_empty() {
        return out;
    }
    let lmin = finite.iter().copied().fold(f64::INFINITY, f64::min).ln();
    let lmax = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max).ln();
    let span = (lmax - lmin).max(1e-9);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    for (label, v) in labels.iter().zip(values) {
        let bar = if v.is_finite() && *v > 0.0 {
            let frac = (v.ln() - lmin) / span;
            let n = 1 + (frac * (width.saturating_sub(1)) as f64).round() as usize;
            "#".repeat(n)
        } else {
            "(n/a)".to_string()
        };
        let _ = writeln!(out, "{label:<lw$}  {bar} {v:.3e}");
    }
    out
}

/// An ASCII scatter plot on log-log axes. Each point is `(x, y, glyph)`;
/// points are drawn in order, so later glyphs win contended cells (the
/// DSE report draws dominated points first and frontier points last).
/// Non-finite or non-positive coordinates are skipped.
pub fn scatter_plot(
    title: &str,
    points: &[(f64, f64, char)],
    width: usize,
    height: usize,
) -> String {
    let width = width.max(2);
    let height = height.max(2);
    let mut out = String::new();
    let _ = writeln!(out, "-- {title} --");
    let finite: Vec<(f64, f64, char)> = points
        .iter()
        .copied()
        .filter(|(x, y, _)| x.is_finite() && *x > 0.0 && y.is_finite() && *y > 0.0)
        .collect();
    if finite.is_empty() {
        return out;
    }
    let lx: Vec<f64> = finite.iter().map(|(x, _, _)| x.ln()).collect();
    let ly: Vec<f64> = finite.iter().map(|(_, y, _)| y.ln()).collect();
    let xmin = lx.iter().copied().fold(f64::INFINITY, f64::min);
    let xmax = lx.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let ymin = ly.iter().copied().fold(f64::INFINITY, f64::min);
    let ymax = ly.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let xspan = (xmax - xmin).max(1e-9);
    let yspan = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for (i, &(_, _, glyph)) in finite.iter().enumerate() {
        let cx = ((lx[i] - xmin) / xspan * (width - 1) as f64).round() as usize;
        let cy = ((ly[i] - ymin) / yspan * (height - 1) as f64).round() as usize;
        grid[height - 1 - cy][cx.min(width - 1)] = glyph;
    }
    for row in &grid {
        let line: String = row.iter().collect();
        let _ = writeln!(out, "|{line}|");
    }
    let _ = writeln!(
        out,
        "x: {:.2e}..{:.2e}  y: {:.2e}..{:.2e}  (log-log)",
        xmin.exp(),
        xmax.exp(),
        ymin.exp(),
        ymax.exp()
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn rollup_renders_under_separator_and_in_csv() {
        let mut t = Table::new("sum", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["b".into(), "2".into()]);
        t.set_rollup(vec!["total".into(), "3".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        // title, header, sep, 2 rows, sep, rollup
        assert_eq!(lines.len(), 7);
        assert!(lines[6].starts_with("total"));
        assert!(lines[5].starts_with('-'));
        let csv = t.to_csv();
        assert!(csv.trim_end().ends_with("total,3"));
    }

    #[test]
    fn grouping_separates_runs() {
        let mut t = Table::new("", &["grp", "v"]);
        t.group_by(0);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["x".into(), "2".into()]);
        t.row(vec!["y".into(), "3".into()]);
        let s = t.render();
        // header, sep, 2 x-rows, blank, 1 y-row
        assert_eq!(s.lines().count(), 6);
        assert!(s.contains("\n\ny"));
    }

    #[test]
    #[should_panic(expected = "rollup width mismatch")]
    fn rollup_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.set_rollup(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "2".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn normalize_min_is_one() {
        let n = normalize_to_min(&[4.0, 2.0, 8.0]);
        assert_eq!(n, vec![2.0, 1.0, 4.0]);
    }

    #[test]
    fn scatter_plot_places_points_and_skips_bad_ones() {
        // glyphs chosen to never collide with axis-label text
        let s = scatter_plot(
            "trade-off",
            &[
                (1.0, 1.0, '@'),
                (100.0, 0.01, '*'),
                (f64::INFINITY, 1.0, '#'),
                (-1.0, 1.0, '#'),
            ],
            20,
            8,
        );
        assert!(s.contains("-- trade-off --"));
        assert!(s.contains('@') && s.contains('*'));
        assert!(!s.contains('#'), "non-finite/non-positive points skipped");
        assert!(s.contains("(log-log)"));
        // empty input renders just the title
        let empty = scatter_plot("e", &[], 20, 8);
        assert_eq!(empty.lines().count(), 1);
    }

    #[test]
    fn scatter_plot_later_points_win_cells() {
        // two points in the same cell: the later glyph is drawn
        let s = scatter_plot("t", &[(1.0, 1.0, '@'), (1.0, 1.0, '*')], 10, 4);
        assert!(s.contains('*'));
        assert!(!s.contains('@'));
    }

    #[test]
    fn bar_chart_handles_log_range() {
        let s = bar_chart(
            "t",
            &["a".into(), "b".into()],
            &[1e-9, 1e-3],
            40,
        );
        assert!(s.contains("a"));
        assert!(s.contains("#"));
    }
}
