//! The paper's evaluation workloads: Table IV DNN layers (ResNet50, DLRM,
//! BERT from MLPerf) and Table III tensor contractions (TCCG benchmark
//! suite: intensli2, ccsd7, ccsd-t4).

use super::Workload;

/// Table IV — ResNet50 representative layers (CONV2D).
///
/// * ResNet50-1: N=32 K=C=64 X=Y=56 R=S=1
/// * ResNet50-2: N=32 K=C=64 X=Y=56 R=S=3
/// * ResNet50-3: N=32 K=512 C=1024 X=Y=14 R=S=1
pub fn resnet50_layers() -> Vec<Workload> {
    vec![
        Workload::conv2d("ResNet50-1", 32, 64, 64, 56, 56, 1, 1, 1),
        Workload::conv2d("ResNet50-2", 32, 64, 64, 56, 56, 3, 3, 1),
        Workload::conv2d("ResNet50-3", 32, 512, 1024, 14, 14, 1, 1, 1),
    ]
}

/// Table IV — DLRM fully-connected layers (GEMM: M=N batch, K=NIN, N=NON).
///
/// * DLRM-1: N=512 NIN=1024 NON=1024
/// * DLRM-2: N=512 NIN=1024 NON=64
/// * DLRM-3: N=512 NIN=2048 NON=2048
pub fn dlrm_layers() -> Vec<Workload> {
    vec![
        Workload::gemm("DLRM-1", 512, 1024, 1024),
        Workload::gemm("DLRM-2", 512, 64, 1024),
        Workload::gemm("DLRM-3", 512, 2048, 2048),
    ]
}

/// Table IV — BERT fully-connected layers.
///
/// * BERT-1: N=256 NIN=768 NON=768
/// * BERT-2: N=256 NIN=3072 NON=768
/// * BERT-3: N=256 NIN=768 NON=3072
pub fn bert_layers() -> Vec<Workload> {
    vec![
        Workload::gemm("BERT-1", 256, 768, 768),
        Workload::gemm("BERT-2", 256, 768, 3072),
        Workload::gemm("BERT-3", 256, 3072, 768),
    ]
}

/// All nine Table IV DNN workloads, in the paper's order.
pub fn dnn_workloads() -> Vec<Workload> {
    let mut v = resnet50_layers();
    v.extend(dlrm_layers());
    v.extend(bert_layers());
    v
}

/// One Table III TCCG problem family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TcSpec {
    pub name: &'static str,
    pub equation: &'static str,
    pub indices: &'static str,
    /// The Tensor Dimension Sizes the paper evaluates for this problem
    /// (Fig. 8: 16/64 for intensli2 and ccsd7, 16/32 for ccsd-t4).
    pub tds_values: [u64; 2],
}

/// Table III — the three TCCG tensor contractions.
pub const TCCG: [TcSpec; 3] = [
    TcSpec {
        name: "intensli2",
        // C[a,b,c,d] = A[d,b,e,a] * B[e,c]
        equation: "dbea,ec->abcd",
        indices: "abcde",
        tds_values: [16, 64],
    },
    TcSpec {
        name: "ccsd7",
        // C[a,b,c] = A[a,d,e,c] * B[e,b,d]
        equation: "adec,ebd->abc",
        indices: "abcde",
        tds_values: [16, 64],
    },
    TcSpec {
        name: "ccsd-t4",
        // C[a,b,c,d,e,f] = A[d,f,g,b] * B[g,e,a,c]
        equation: "dfgb,geac->abcdef",
        indices: "abcdefg",
        tds_values: [16, 32],
    },
];

/// Build a Table III TC workload at a given Tensor Dimension Size (every
/// index gets extent `tds`, per §V).
pub fn tccg_problem(spec: &TcSpec, tds: u64) -> Workload {
    let extents: Vec<(char, u64)> = spec.indices.chars().map(|c| (c, tds)).collect();
    Workload::tc(&format!("{}_tds{}", spec.name, tds), spec.equation, &extents)
}

/// All Fig. 8 TC workload instances: (spec, tds, workload).
pub fn tc_workloads() -> Vec<(&'static TcSpec, u64, Workload)> {
    TCCG.iter()
        .flat_map(|spec| {
            spec.tds_values
                .iter()
                .map(move |&tds| (spec, tds, tccg_problem(spec, tds)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::ttgt_gemm;

    #[test]
    fn table_iv_has_nine_workloads() {
        let w = dnn_workloads();
        assert_eq!(w.len(), 9);
        assert_eq!(w[0].name, "ResNet50-1");
        assert_eq!(w[8].name, "BERT-3");
    }

    #[test]
    fn dlrm2_dimensions_match_table_iv() {
        let p = dlrm_layers()[1].problem();
        // N=512 NIN=1024 NON=64 -> GEMM M=512 N=64 K=1024
        assert_eq!(p.dims[p.dim_index("M").unwrap()].size, 512);
        assert_eq!(p.dims[p.dim_index("N").unwrap()].size, 64);
        assert_eq!(p.dims[p.dim_index("K").unwrap()].size, 1024);
    }

    #[test]
    fn resnet_macs_are_plausible() {
        let layers = resnet50_layers();
        // ResNet50-2 (3x3) has 9x the MACs of ResNet50-1 (1x1)
        assert_eq!(layers[1].macs(), layers[0].macs() * 9);
    }

    /// The Table III TTGT GEMM dimension sizes, exactly as printed.
    #[test]
    fn table_iii_gemm_dims_exact() {
        let cases: [(&str, u64, (u64, u64, u64)); 6] = [
            ("intensli2", 64, (262144, 64, 64)),
            ("intensli2", 16, (4096, 16, 16)),
            ("ccsd7", 64, (4096, 64, 4096)),
            ("ccsd7", 16, (256, 16, 256)),
            ("ccsd-t4", 32, (32768, 32768, 32)),
            ("ccsd-t4", 16, (4096, 4096, 16)),
        ];
        for (name, tds, (m, n, k)) in cases {
            let spec = TCCG.iter().find(|s| s.name == name).unwrap();
            let w = tccg_problem(spec, tds);
            let plan = ttgt_gemm(&w).unwrap();
            assert_eq!((plan.m, plan.n, plan.k), (m, n, k), "{name} TDS={tds}");
        }
    }

    #[test]
    fn tc_workloads_cover_fig8() {
        let all = tc_workloads();
        assert_eq!(all.len(), 6);
    }

    #[test]
    fn tccg_problems_validate() {
        for (_, _, w) in tc_workloads() {
            w.problem().validate().unwrap();
        }
    }
}
