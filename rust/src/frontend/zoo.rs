//! The paper's evaluation workloads: Table IV DNN layers (ResNet50, DLRM,
//! BERT from MLPerf) and Table III tensor contractions (TCCG benchmark
//! suite: intensli2, ccsd7, ccsd-t4) — plus the full 53-conv ResNet-50
//! network for end-to-end (network-level) co-design.
//!
//! Zoo entries are [`WorkloadGraph`]s: ordered layer lists with repeat
//! counts, consumable whole by the network orchestrator or layer by
//! layer (the graphs offer `Vec`-like indexing/`remove`/iteration) by
//! the per-figure experiment drivers.

use crate::network::WorkloadGraph;

use super::Workload;

/// Table IV — ResNet50 representative layers (CONV2D).
///
/// * ResNet50-1: N=32 K=C=64 X=Y=56 R=S=1
/// * ResNet50-2: N=32 K=C=64 X=Y=56 R=S=3
/// * ResNet50-3: N=32 K=512 C=1024 X=Y=14 R=S=1
pub fn resnet50_layers() -> WorkloadGraph {
    WorkloadGraph::from_workloads(
        "ResNet50-TableIV",
        vec![
            Workload::conv2d("ResNet50-1", 32, 64, 64, 56, 56, 1, 1, 1),
            Workload::conv2d("ResNet50-2", 32, 64, 64, 56, 56, 3, 3, 1),
            Workload::conv2d("ResNet50-3", 32, 512, 1024, 14, 14, 1, 1, 1),
        ],
    )
}

/// Table IV — DLRM fully-connected layers (GEMM: M=N batch, K=NIN, N=NON).
///
/// * DLRM-1: N=512 NIN=1024 NON=1024
/// * DLRM-2: N=512 NIN=1024 NON=64
/// * DLRM-3: N=512 NIN=2048 NON=2048
pub fn dlrm_layers() -> WorkloadGraph {
    WorkloadGraph::from_workloads(
        "DLRM",
        vec![
            Workload::gemm("DLRM-1", 512, 1024, 1024),
            Workload::gemm("DLRM-2", 512, 64, 1024),
            Workload::gemm("DLRM-3", 512, 2048, 2048),
        ],
    )
}

/// Table IV — BERT fully-connected layers.
///
/// * BERT-1: N=256 NIN=768 NON=768
/// * BERT-2: N=256 NIN=3072 NON=768
/// * BERT-3: N=256 NIN=768 NON=3072
pub fn bert_layers() -> WorkloadGraph {
    WorkloadGraph::from_workloads(
        "BERT",
        vec![
            Workload::gemm("BERT-1", 256, 768, 768),
            Workload::gemm("BERT-2", 256, 768, 3072),
            Workload::gemm("BERT-3", 256, 3072, 768),
        ],
    )
}

/// All nine Table IV DNN workloads, in the paper's order.
pub fn dnn_workloads() -> WorkloadGraph {
    let mut g = WorkloadGraph::from_workloads("TableIV-DNN9", resnet50_layers().workloads());
    for w in dlrm_layers().workloads() {
        g.add(w);
    }
    for w in bert_layers().workloads() {
        g.add(w);
    }
    g
}

/// The full ResNet-50 (v1.5 bottleneck placement: the stride-2 conv is
/// the 3×3 of each downsampling block), batch `n`, ImageNet 224×224
/// input — 53 convolutions plus the final 1000-way FC as a GEMM.
///
/// Layer names follow `convS_Bx` (stage, block, position); identical
/// consecutive interior blocks compress into repeat-counted nodes, and
/// only ~23 of the 53 conv shapes are distinct — which is exactly what
/// the network orchestrator's cross-layer dedup exploits.
///
/// Sizes are output-size semantics (`x`/`y` are output extents), so
/// e.g. conv1 is 7×7 stride 2 producing 112×112 from the 224×224 input.
pub fn resnet50_full(n: u64) -> WorkloadGraph {
    let mut g = WorkloadGraph::new("ResNet50");
    // conv1: 3 -> 64, 7x7 / s2, out 112x112
    g.add(Workload::conv2d("conv1", n, 64, 3, 112, 112, 7, 7, 2));
    // (3x3/s2 maxpool -> 56x56, not a tensor-op workload)

    // bottleneck stages: (stage, blocks, width, in_ch, out_ch, out_xy)
    // in_ch is the input channel count of the stage's FIRST block; every
    // later block takes out_ch. Stage 2 keeps 56x56 (stride 1); stages
    // 3-5 halve the spatial extent in block 1's 3x3 conv.
    let stages: [(usize, u64, u64, u64, u64, u64); 4] = [
        (2, 3, 64, 64, 256, 56),
        (3, 4, 128, 256, 512, 28),
        (4, 6, 256, 512, 1024, 14),
        (5, 3, 512, 1024, 2048, 7),
    ];
    for (stage, blocks, width, in_ch, out_ch, out) in stages {
        let first = stage == 2; // stage 2 downsamples via the maxpool, not the conv
        let (stride, in_xy) = if first { (1, out) } else { (2, out * 2) };
        let name = |pos: &str| format!("conv{stage}_{pos}");
        // block 1 (projection block)
        g.add(Workload::conv2d(&name("1a"), n, width, in_ch, in_xy, in_xy, 1, 1, 1));
        g.add(Workload::conv2d(&name("1b"), n, width, width, out, out, 3, 3, stride));
        g.add(Workload::conv2d(&name("1c"), n, out_ch, width, out, out, 1, 1, 1));
        g.add(Workload::conv2d(&name("ds"), n, out_ch, in_ch, out, out, 1, 1, stride));
        // interior identity blocks (identical shapes -> repeat-counted)
        let rep = blocks - 1;
        g.add_repeated(Workload::conv2d(&name("xa"), n, width, out_ch, out, out, 1, 1, 1), rep);
        g.add_repeated(Workload::conv2d(&name("xb"), n, width, width, out, out, 3, 3, 1), rep);
        g.add_repeated(Workload::conv2d(&name("xc"), n, out_ch, width, out, out, 1, 1, 1), rep);
    }

    // global average pool (not a tensor-op workload), then the classifier
    g.add(Workload::gemm("fc1000", n, 1000, 2048));
    g
}

/// Sparse-scenario suite — SpMM: one sparse operand (a pruned weight
/// matrix or a graph adjacency block) against a dense activation
/// matrix. Structurally these are GEMMs (density is *not* a problem
/// parameter — it rides on the cost kind, e.g.
/// `--cost sparse-analytical:d=0.1`, so one suite serves every density
/// in a sweep). Shapes: a square graph-style block, a tall-skinny
/// embedding reduction, and a BERT-FFN-style projection.
pub fn spmm_workloads() -> WorkloadGraph {
    WorkloadGraph::from_workloads(
        "SpMM",
        vec![
            Workload::gemm("SpMM-1", 1024, 1024, 1024),
            Workload::gemm("SpMM-2", 512, 64, 2048),
            Workload::gemm("SpMM-3", 256, 3072, 768),
        ],
    )
}

/// Sparse-scenario suite — SpGEMM: both operands sparse (graph
/// analytics / sparse-transformer attention shapes). The sparse cost
/// kind scales effective MACs by the *product* of input densities, so
/// these shapes exercise the quadratic-compute-savings regime and the
/// output-densification bound (`1 - (1 - d²)^K` saturates fast at the
/// large K below).
pub fn spgemm_workloads() -> WorkloadGraph {
    WorkloadGraph::from_workloads(
        "SpGEMM",
        vec![
            Workload::gemm("SpGEMM-1", 2048, 2048, 2048),
            Workload::gemm("SpGEMM-2", 4096, 4096, 256),
        ],
    )
}

/// Magnitude-pruned ResNet-50 representative layers with per-layer
/// input densities: early layers keep most weights, deep layers prune
/// hardest (the usual magnitude-pruning profile). Consumed by the
/// density-sweep case study's per-layer section, which builds one
/// sparse cost kind per layer from the paired density.
pub fn pruned_resnet_layers() -> Vec<(Workload, f64)> {
    vec![
        (Workload::conv2d("ResNet50-1", 32, 64, 64, 56, 56, 1, 1, 1), 0.9),
        (Workload::conv2d("ResNet50-2", 32, 64, 64, 56, 56, 3, 3, 1), 0.5),
        (Workload::conv2d("ResNet50-3", 32, 512, 1024, 14, 14, 1, 1, 1), 0.2),
    ]
}

/// The whole sparse suite (SpMM + SpGEMM), in order — what the
/// density-sweep case study iterates per density.
pub fn sparse_suite() -> WorkloadGraph {
    let mut g = WorkloadGraph::from_workloads("SparseSuite", spmm_workloads().workloads());
    for w in spgemm_workloads().workloads() {
        g.add(w);
    }
    g
}

/// One Table III TCCG problem family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TcSpec {
    pub name: &'static str,
    pub equation: &'static str,
    pub indices: &'static str,
    /// The Tensor Dimension Sizes the paper evaluates for this problem
    /// (Fig. 8: 16/64 for intensli2 and ccsd7, 16/32 for ccsd-t4).
    pub tds_values: [u64; 2],
}

/// Table III — the three TCCG tensor contractions.
pub const TCCG: [TcSpec; 3] = [
    TcSpec {
        name: "intensli2",
        // C[a,b,c,d] = A[d,b,e,a] * B[e,c]
        equation: "dbea,ec->abcd",
        indices: "abcde",
        tds_values: [16, 64],
    },
    TcSpec {
        name: "ccsd7",
        // C[a,b,c] = A[a,d,e,c] * B[e,b,d]
        equation: "adec,ebd->abc",
        indices: "abcde",
        tds_values: [16, 64],
    },
    TcSpec {
        name: "ccsd-t4",
        // C[a,b,c,d,e,f] = A[d,f,g,b] * B[g,e,a,c]
        equation: "dfgb,geac->abcdef",
        indices: "abcdefg",
        tds_values: [16, 32],
    },
];

/// Build a Table III TC workload at a given Tensor Dimension Size (every
/// index gets extent `tds`, per §V).
pub fn tccg_problem(spec: &TcSpec, tds: u64) -> Workload {
    let extents: Vec<(char, u64)> = spec.indices.chars().map(|c| (c, tds)).collect();
    Workload::tc(&format!("{}_tds{}", spec.name, tds), spec.equation, &extents)
}

/// All Fig. 8 TC workload instances: (spec, tds, workload).
pub fn tc_workloads() -> Vec<(&'static TcSpec, u64, Workload)> {
    TCCG.iter()
        .flat_map(|spec| {
            spec.tds_values
                .iter()
                .map(move |&tds| (spec, tds, tccg_problem(spec, tds)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::ttgt_gemm;

    #[test]
    fn table_iv_has_nine_workloads() {
        let w = dnn_workloads();
        assert_eq!(w.len(), 9);
        assert_eq!(w[0].name, "ResNet50-1");
        assert_eq!(w[8].name, "BERT-3");
    }

    #[test]
    fn dlrm2_dimensions_match_table_iv() {
        let p = dlrm_layers()[1].problem();
        // N=512 NIN=1024 NON=64 -> GEMM M=512 N=64 K=1024
        assert_eq!(p.dims[p.dim_index("M").unwrap()].size, 512);
        assert_eq!(p.dims[p.dim_index("N").unwrap()].size, 64);
        assert_eq!(p.dims[p.dim_index("K").unwrap()].size, 1024);
    }

    #[test]
    fn resnet_macs_are_plausible() {
        let layers = resnet50_layers();
        // ResNet50-2 (3x3) has 9x the MACs of ResNet50-1 (1x1)
        assert_eq!(layers[1].macs(), layers[0].macs() * 9);
    }

    #[test]
    fn resnet50_full_counts_match_the_network() {
        let g = resnet50_full(1);
        // 53 convolutions + 1 FC layer
        assert_eq!(g.total_layers(), 54);
        let convs: u64 = g
            .nodes()
            .iter()
            .filter(|node| {
                matches!(node.workload.kind, crate::frontend::WorkloadKind::Conv2d { .. })
            })
            .map(|node| node.repeat)
            .sum();
        assert_eq!(convs, 53);
        // repeat counts compress the interior blocks
        assert!(g.len() < 54, "graph should be repeat-compressed, got {} nodes", g.len());
        // ~3.9 GMACs at batch 1 (He et al. report 3.8 GFLOPs as mult-adds
        // for the v1 placement; v1.5 is slightly heavier)
        let macs = g.total_macs();
        assert!((3_500_000_000..4_500_000_000).contains(&macs), "got {macs}");
        // batch scales MACs linearly
        assert_eq!(resnet50_full(4).total_macs(), 4 * macs);
    }

    #[test]
    fn resnet50_full_stage_shapes() {
        let g = resnet50_full(2);
        let find = |name: &str| -> Workload {
            g.iter()
                .find(|w| w.name == name)
                .unwrap_or_else(|| panic!("layer {name} missing"))
                .clone()
        };
        // spot-check the downsampling 3x3 of stage 3: 128ch, 28x28 out, s2
        match find("conv3_1b").kind {
            crate::frontend::WorkloadKind::Conv2d { n, k, c, x, y, r, s, stride } => {
                assert_eq!((n, k, c, x, y, r, s, stride), (2, 128, 128, 28, 28, 3, 3, 2));
            }
            other => panic!("conv3_1b is {other:?}"),
        }
        // classifier GEMM: batch x 1000 over 2048 features
        match find("fc1000").kind {
            crate::frontend::WorkloadKind::Gemm { m, n, k } => {
                assert_eq!((m, n, k), (2, 1000, 2048));
            }
            other => panic!("fc1000 is {other:?}"),
        }
    }

    /// The Table III TTGT GEMM dimension sizes, exactly as printed.
    #[test]
    fn table_iii_gemm_dims_exact() {
        let cases: [(&str, u64, (u64, u64, u64)); 6] = [
            ("intensli2", 64, (262144, 64, 64)),
            ("intensli2", 16, (4096, 16, 16)),
            ("ccsd7", 64, (4096, 64, 4096)),
            ("ccsd7", 16, (256, 16, 256)),
            ("ccsd-t4", 32, (32768, 32768, 32)),
            ("ccsd-t4", 16, (4096, 4096, 16)),
        ];
        for (name, tds, (m, n, k)) in cases {
            let spec = TCCG.iter().find(|s| s.name == name).unwrap();
            let w = tccg_problem(spec, tds);
            let plan = ttgt_gemm(&w).unwrap();
            assert_eq!((plan.m, plan.n, plan.k), (m, n, k), "{name} TDS={tds}");
        }
    }

    #[test]
    fn sparse_suite_is_well_formed() {
        let suite = sparse_suite();
        assert_eq!(suite.len(), spmm_workloads().len() + spgemm_workloads().len());
        for w in suite.iter() {
            w.problem().validate().unwrap();
        }
        let pruned = pruned_resnet_layers();
        assert_eq!(pruned.len(), 3);
        for (w, d) in &pruned {
            w.problem().validate().unwrap();
            assert!((0.0..=1.0).contains(d), "{}: density {d} out of range", w.name);
        }
        // the pruning profile deepens: later layers are sparser
        assert!(pruned.windows(2).all(|p| p[0].1 >= p[1].1));
    }

    #[test]
    fn tc_workloads_cover_fig8() {
        let all = tc_workloads();
        assert_eq!(all.len(), 6);
    }

    #[test]
    fn tccg_problems_validate() {
        for (_, _, w) in tc_workloads() {
            w.problem().validate().unwrap();
        }
    }
}
