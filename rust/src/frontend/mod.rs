//! Union **frontend**: the workload zoo used in the paper's evaluation
//! (Tables III & IV) and the algorithm transforms the frontend can apply
//! before handing a problem to the optimizer (im2col, TTGT — §II-A, §V-A).
//!
//! A [`Workload`] is the frontend-level description (what TensorFlow or
//! the COMET DSL would provide). It can be turned into a mini-MLIR module
//! ([`Workload::to_ir`]), lowered through the dialect pipeline
//! ([`Workload::lower`]) and extracted as a Union [`Problem`] — or, for
//! convenience, converted to a [`Problem`] directly via builders that are
//! *tested equal* to the full IR path.

mod transforms;
mod zoo;

pub use transforms::{im2col_gemm, ttgt_gemm, TtgtPlan};
pub use zoo::{
    bert_layers, dlrm_layers, dnn_workloads, pruned_resnet_layers, resnet50_full, resnet50_layers,
    sparse_suite, spgemm_workloads, spmm_workloads, tc_workloads, tccg_problem, TcSpec, TCCG,
};

use crate::ir::core::{DType, Module, Type};
use crate::ir::dialects::{ta, tosa};
use crate::ir::lower::{linalg_to_affine, lower_to_linalg};
use crate::problem::{self, Problem};

/// A frontend-level tensor workload.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub name: String,
    pub kind: WorkloadKind,
}

/// The supported workload shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadKind {
    /// CONV2D with output-size semantics (Algorithm 1).
    Conv2d { n: u64, k: u64, c: u64, x: u64, y: u64, r: u64, s: u64, stride: u64 },
    /// GEMM `M×K · K×N` (fully-connected layers lower to this).
    Gemm { m: u64, n: u64, k: u64 },
    /// Tensor contraction: einsum equation + per-index extents.
    Tc { equation: String, extents: Vec<(char, u64)> },
}

impl Workload {
    pub fn conv2d(
        name: &str,
        n: u64,
        k: u64,
        c: u64,
        x: u64,
        y: u64,
        r: u64,
        s: u64,
        stride: u64,
    ) -> Workload {
        Workload { name: name.into(), kind: WorkloadKind::Conv2d { n, k, c, x, y, r, s, stride } }
    }

    pub fn gemm(name: &str, m: u64, n: u64, k: u64) -> Workload {
        Workload { name: name.into(), kind: WorkloadKind::Gemm { m, n, k } }
    }

    pub fn tc(name: &str, equation: &str, extents: &[(char, u64)]) -> Workload {
        Workload {
            name: name.into(),
            kind: WorkloadKind::Tc { equation: equation.into(), extents: extents.to_vec() },
        }
    }

    /// Build the frontend IR module (tosa ops for ML workloads, ta ops for
    /// HPC workloads) — what the TF/COMET importers would emit.
    pub fn to_ir(&self) -> Module {
        let mut m = Module::new(&self.name);
        match &self.kind {
            WorkloadKind::Conv2d { n, k, c, x, y, r, s, stride } => {
                // input H = (X-1)*stride + R (output-size semantics)
                let h = (x - 1) * stride + r;
                let w = (y - 1) * stride + s;
                let input = m.new_value("I", Type::tensor(&[*n, h, w, *c], DType::F32));
                let weight = m.new_value("W", Type::tensor(&[*k, *r, *s, *c], DType::F32));
                let (op, _) = tosa::conv2d(&mut m, input, weight, (*stride, *stride));
                m.ops.push(op);
            }
            WorkloadKind::Gemm { m: mm, n, k } => {
                let a = m.new_value("A", Type::tensor(&[*mm, *k], DType::F32));
                let b = m.new_value("B", Type::tensor(&[*k, *n], DType::F32));
                let (op, _) = tosa::matmul(&mut m, a, b);
                m.ops.push(op);
            }
            WorkloadKind::Tc { equation, extents } => {
                let (ain, bin, _) = ta::parse_equation(equation);
                let extent = |c: char| -> u64 {
                    extents
                        .iter()
                        .find(|(e, _)| *e == c)
                        .unwrap_or_else(|| panic!("extent for index {c} missing"))
                        .1
                };
                let ashape: Vec<u64> = ain.iter().map(|&c| extent(c)).collect();
                let bshape: Vec<u64> = bin.iter().map(|&c| extent(c)).collect();
                let a = m.new_value("A", Type::tensor(&ashape, DType::F32));
                let b = m.new_value("B", Type::tensor(&bshape, DType::F32));
                let (op, _) = ta::contract(&mut m, equation, a, b);
                m.ops.push(op);
            }
        }
        m
    }

    /// Lower through the full dialect pipeline to an affine module.
    /// `use_ttgt` selects the COMET TTGT rewrite for TC workloads.
    pub fn lower(&self, use_ttgt: bool) -> Module {
        linalg_to_affine(&lower_to_linalg(&self.to_ir(), use_ttgt))
    }

    /// Extract the Union problem via the IR pipeline.
    pub fn problem_via_ir(&self, use_ttgt: bool) -> Result<Problem, String> {
        let mut p = crate::problem::problem_from_affine(&self.lower(use_ttgt))?;
        p.name = self.name.clone();
        Ok(p)
    }

    /// Direct problem construction (no IR round trip) — tested equivalent
    /// to [`Workload::problem_via_ir`].
    pub fn problem(&self) -> Problem {
        let mut p = match &self.kind {
            WorkloadKind::Conv2d { n, k, c, x, y, r, s, stride } => {
                problem::conv2d(*n, *k, *c, *x, *y, *r, *s, *stride)
            }
            WorkloadKind::Gemm { m, n, k } => problem::gemm(*m, *n, *k),
            WorkloadKind::Tc { equation, extents } => {
                let (ain, bin, cout) = ta::parse_equation(equation);
                let dims: Vec<(String, u64)> = {
                    // output indices then contracted, matching ta_to_linalg
                    let mut order: Vec<char> = cout.clone();
                    order.extend(ain.iter().filter(|c| bin.contains(c) && !cout.contains(c)));
                    order
                        .iter()
                        .map(|c| {
                            let e = extents
                                .iter()
                                .find(|(x, _)| x == c)
                                .unwrap_or_else(|| panic!("extent for {c} missing"))
                                .1;
                            (c.to_uppercase().to_string(), e)
                        })
                        .collect()
                };
                let dims_ref: Vec<(&str, u64)> =
                    dims.iter().map(|(n, s)| (n.as_str(), *s)).collect();
                let names = |cs: &[char]| -> Vec<String> {
                    cs.iter().map(|c| c.to_uppercase().to_string()).collect()
                };
                let a_names = names(&ain);
                let b_names = names(&bin);
                let c_names = names(&cout);
                problem::tensor_contraction(
                    &self.name,
                    &dims_ref,
                    &a_names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
                    &b_names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
                    &c_names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
                )
            }
        };
        p.name = self.name.clone();
        p
    }

    /// Total MACs of this workload.
    pub fn macs(&self) -> u64 {
        self.problem().total_macs()
    }
}

/// Convenience: a GEMM problem without going through a workload.
pub fn gemm_problem(m: u64, n: u64, k: u64) -> Problem {
    problem::gemm(m, n, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_problem_matches_ir_path() {
        let w = Workload::gemm("g", 32, 16, 8);
        let direct = w.problem();
        let via_ir = w.problem_via_ir(false).unwrap();
        assert_eq!(direct.dim_sizes(), via_ir.dim_sizes());
        assert_eq!(direct.total_macs(), via_ir.total_macs());
        assert_eq!(direct.operation, via_ir.operation);
        assert_eq!(direct.reduction_dims(), via_ir.reduction_dims());
    }

    #[test]
    fn conv_problem_matches_ir_path() {
        let w = Workload::conv2d("c", 2, 8, 4, 14, 14, 3, 3, 1);
        let direct = w.problem();
        let via_ir = w.problem_via_ir(false).unwrap();
        assert_eq!(direct.total_macs(), via_ir.total_macs());
        assert_eq!(direct.dims.len(), via_ir.dims.len());
        // footprints agree for the full problem
        for (d_ds, i_ds) in direct.data_spaces.iter().zip(&via_ir.data_spaces) {
            assert_eq!(
                d_ds.full_size(&direct.dims),
                i_ds.full_size(&via_ir.dims),
                "{} vs {}",
                d_ds.name,
                i_ds.name
            );
        }
    }

    #[test]
    fn tc_problem_matches_ir_path() {
        let w = Workload::tc(
            "intensli2",
            "dbea,ec->abcd",
            &[('a', 16), ('b', 16), ('c', 16), ('d', 16), ('e', 16)],
        );
        let direct = w.problem();
        let via_ir = w.problem_via_ir(false).unwrap();
        assert_eq!(direct.total_macs(), via_ir.total_macs());
        assert_eq!(direct.dims.len(), via_ir.dims.len());
    }

    #[test]
    fn conv_strided_input_roundtrip() {
        // output-size semantics: X=28, stride 2, R=3 -> H = 57
        let w = Workload::conv2d("c", 1, 8, 4, 28, 28, 3, 3, 2);
        let p = w.problem_via_ir(false).unwrap();
        assert_eq!(p.dims[p.dim_index("X").unwrap()].size, 28);
    }
}
