//! Frontend **algorithm transforms** (paper §II-A, §V-A): the frontend
//! decides whether to run an operation natively or rewrite it — im2col
//! turns CONV2D into GEMM (the TPU route), TTGT turns a tensor
//! contraction into transpose–transpose–GEMM–transpose (the COMET route).

use super::{Workload, WorkloadKind};
use crate::ir::dialects::ta;

/// A TTGT rewrite plan: the GEMM the contraction collapses to, plus the
/// index groups of each transpose/reshape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TtgtPlan {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Output indices drawn from A (row group).
    pub free_a: Vec<char>,
    /// Output indices drawn from B (column group).
    pub free_b: Vec<char>,
    /// Contracted indices.
    pub contracted: Vec<char>,
}

impl TtgtPlan {
    /// The GEMM workload realizing this plan.
    pub fn gemm_workload(&self, name: &str) -> Workload {
        Workload::gemm(name, self.m, self.n, self.k)
    }

    /// Memory footprint in words of the matricized operands + result —
    /// equal to the native footprint, as the paper notes ("TTGT does not
    /// incur duplicated elements").
    pub fn footprint_words(&self) -> u64 {
        self.m * self.k + self.k * self.n + self.m * self.n
    }
}

/// Compute the TTGT plan of a TC workload (Table III's "GEMM Dimension
/// Sizes"). Errors for non-TC workloads.
pub fn ttgt_gemm(w: &Workload) -> Result<TtgtPlan, String> {
    let WorkloadKind::Tc { equation, extents } = &w.kind else {
        return Err(format!("{} is not a tensor contraction", w.name));
    };
    let (ain, bin, cout) = ta::parse_equation(equation);
    let extent = |c: char| -> Result<u64, String> {
        extents
            .iter()
            .find(|(e, _)| *e == c)
            .map(|(_, s)| *s)
            .ok_or_else(|| format!("missing extent for index {c}"))
    };
    let free_a: Vec<char> = cout.iter().filter(|c| ain.contains(c)).copied().collect();
    let free_b: Vec<char> = cout
        .iter()
        .filter(|c| bin.contains(c) && !free_a.contains(c))
        .copied()
        .collect();
    let contracted: Vec<char> = ain
        .iter()
        .filter(|c| bin.contains(c) && !cout.contains(c))
        .copied()
        .collect();
    if contracted.is_empty() {
        return Err("no contracted index (outer product not supported)".into());
    }
    let prod = |cs: &[char]| -> Result<u64, String> {
        cs.iter().map(|&c| extent(c)).product()
    };
    Ok(TtgtPlan {
        m: prod(&free_a)?,
        n: prod(&free_b)?,
        k: prod(&contracted)?,
        free_a,
        free_b,
        contracted,
    })
}

/// im2col rewrite of a CONV2D workload to GEMM: `M = N·X·Y`, `N = K`,
/// `K = C·R·S` (§II-A: how TPU-class accelerators run convolutions).
pub fn im2col_gemm(w: &Workload) -> Result<Workload, String> {
    let WorkloadKind::Conv2d { n, k, c, x, y, r, s, .. } = &w.kind else {
        return Err(format!("{} is not a CONV2D", w.name));
    };
    Ok(Workload::gemm(
        &format!("{}_im2col", w.name),
        n * x * y,
        *k,
        c * r * s,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttgt_preserves_mac_count() {
        for (_, _, w) in crate::frontend::tc_workloads() {
            let plan = ttgt_gemm(&w).unwrap();
            let gemm = plan.gemm_workload("g");
            assert_eq!(gemm.macs(), w.macs(), "{}", w.name);
        }
    }

    #[test]
    fn ttgt_preserves_footprint() {
        // "the memory footprint for both running TC natively and running
        // TC with TTGT have the same memory footprint" (§V-A)
        for (_, _, w) in crate::frontend::tc_workloads() {
            let plan = ttgt_gemm(&w).unwrap();
            let p = w.problem();
            let native: u64 = p
                .data_spaces
                .iter()
                .map(|ds| ds.full_size(&p.dims))
                .sum();
            assert_eq!(plan.footprint_words(), native, "{}", w.name);
        }
    }

    #[test]
    fn im2col_preserves_mac_count() {
        for w in crate::frontend::resnet50_layers() {
            let g = im2col_gemm(&w).unwrap();
            assert_eq!(g.macs(), w.macs(), "{}", w.name);
        }
    }

    #[test]
    fn im2col_rejects_gemm() {
        assert!(im2col_gemm(&Workload::gemm("g", 2, 2, 2)).is_err());
    }

    #[test]
    fn ttgt_rejects_conv() {
        assert!(ttgt_gemm(&Workload::conv2d("c", 1, 1, 1, 2, 2, 1, 1, 1)).is_err());
    }

    #[test]
    fn ttgt_groups_partition_indices() {
        let w = crate::frontend::tccg_problem(&crate::frontend::TCCG[2], 16); // ccsd-t4
        let plan = ttgt_gemm(&w).unwrap();
        assert_eq!(plan.free_a, vec!['b', 'd', 'f']);
        assert_eq!(plan.free_b, vec!['a', 'c', 'e']);
        assert_eq!(plan.contracted, vec!['g']);
    }
}
