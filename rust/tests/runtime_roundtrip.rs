//! Integration: the PJRT runtime path — load AOT artifacts (built by
//! `make artifacts`), execute, and validate numerics against Rust
//! references. Skipped (with a notice) when artifacts are absent so
//! `cargo test` works on a fresh checkout.

use union::runtime::{
    artifacts_available, artifacts_dir, max_abs_diff, random_tensor, reference_gemm, Runtime,
};

fn need_artifacts() -> bool {
    if !artifacts_available() {
        eprintln!("NOTE: artifacts/ not built; run `make artifacts` to enable runtime tests");
        return false;
    }
    if !union::runtime::runtime_available() {
        eprintln!("NOTE: built without the `pjrt` feature; skipping runtime tests");
        return false;
    }
    true
}

#[test]
fn gemm_artifact_matches_rust_reference() {
    if !need_artifacts() {
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let exe = rt.load_artifact(&artifacts_dir(), "gemm_128").expect("load");
    let (m, n, k) = (128, 128, 128);
    let a = random_tensor(m * k, 10);
    let b = random_tensor(k * n, 11);
    let out = exe.run_f32(&[(&a, &[m, k]), (&b, &[k, n])]).expect("run");
    assert_eq!(out.output.len(), m * n);
    let reference = reference_gemm(&a, &b, m, n, k);
    let diff = max_abs_diff(&out.output, &reference);
    assert!(diff < 1e-3, "max diff {diff}");
}

#[test]
fn ttgt_equals_native_tc_numerically() {
    if !need_artifacts() {
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let dir = artifacts_dir();
    let native = rt.load_artifact(&dir, "tc_intensli2_native").expect("load native");
    let ttgt = rt.load_artifact(&dir, "tc_intensli2_ttgt").expect("load ttgt");
    let tds = 16;
    let a = random_tensor(tds * tds * tds * tds, 20);
    let b = random_tensor(tds * tds, 21);
    let rn = native
        .run_f32(&[(&a, &[tds, tds, tds, tds]), (&b, &[tds, tds])])
        .expect("run native");
    let rt_ = ttgt
        .run_f32(&[(&a, &[tds, tds, tds, tds]), (&b, &[tds, tds])])
        .expect("run ttgt");
    assert_eq!(rn.output.len(), rt_.output.len());
    let diff = max_abs_diff(&rn.output, &rt_.output);
    assert!(diff < 1e-3, "TTGT != native: {diff}");
}

#[test]
fn im2col_equals_direct_conv_numerically() {
    if !need_artifacts() {
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let dir = artifacts_dir();
    let direct = rt.load_artifact(&dir, "conv2d_direct").expect("load direct");
    let im2col = rt.load_artifact(&dir, "conv2d_im2col").expect("load im2col");
    let x = random_tensor(2 * 16 * 16 * 8, 30);
    let w = random_tensor(16 * 3 * 3 * 8, 31);
    let rd = direct
        .run_f32(&[(&x, &[2, 16, 16, 8]), (&w, &[16, 3, 3, 8])])
        .expect("run direct");
    let ri = im2col
        .run_f32(&[(&x, &[2, 16, 16, 8]), (&w, &[16, 3, 3, 8])])
        .expect("run im2col");
    let diff = max_abs_diff(&rd.output, &ri.output);
    assert!(diff < 1e-3, "im2col != direct: {diff}");
}

#[test]
fn full_validation_routine() {
    if !need_artifacts() {
        return;
    }
    union::runtime::validate_artifacts(&artifacts_dir()).expect("validation");
}

#[test]
fn wide_gemm_artifact_runs() {
    if !need_artifacts() {
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let exe = rt
        .load_artifact(&artifacts_dir(), "gemm_512x64x1024")
        .expect("load");
    // DLRM-2 shape: [512,1024] x [1024,64]
    let a = random_tensor(512 * 1024, 40);
    let b = random_tensor(1024 * 64, 41);
    let out = exe.run_f32(&[(&a, &[512, 1024]), (&b, &[1024, 64])]).expect("run");
    assert_eq!(out.output.len(), 512 * 64);
    // spot-check one element against the reference
    let reference = reference_gemm(&a, &b, 512, 64, 1024);
    let diff = max_abs_diff(&out.output, &reference);
    assert!(diff < 1e-2, "max diff {diff}");
}
