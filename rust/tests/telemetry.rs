//! Integration tests for the telemetry subsystem: registry exposition,
//! `MetricSource` unification, flight-recorder semantics, stats-merge
//! arithmetic — and the load-bearing invariant that telemetry **never
//! changes search results** (recording on is bit-identical to the
//! pre-telemetry engine).
//!
//! The registry and recorder are process-global; tests here only ever
//! *add* observations and assert on deltas or on names they alone use,
//! so they stay order- and concurrency-independent.

use union::engine::{EngineStats, Session};
use union::mappers::{Mapper, Objective, RandomMapper};
use union::telemetry::{self, FlightRecorder, HistogramSnapshot, MetricSource};

#[test]
fn registry_round_trips_through_scalars_and_snapshots() {
    telemetry::counter("it_requests_total").add(3);
    telemetry::gauge("it_depth").set(7);
    telemetry::histogram("it_latency_us").record(100);
    telemetry::histogram("it_latency_us").record(100_000);

    let scalars = telemetry::registry().scalars();
    let get = |name: &str| scalars.iter().find(|(n, _)| n == name).map(|&(_, v)| v);
    assert!(get("it_requests_total") >= Some(3), "counter visible in scalars");
    assert_eq!(get("it_depth"), Some(7), "gauge visible in scalars");
    // scalars are sorted by name — the wire exposition relies on it
    let names: Vec<&str> = scalars.iter().map(|(n, _)| n.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names, sorted, "scalars() must be name-sorted");

    let hists = telemetry::registry().histogram_snapshots();
    let (_, snap) = hists
        .iter()
        .find(|(n, _)| n == "it_latency_us")
        .expect("histogram visible in snapshots");
    assert!(snap.count >= 2);
    assert!(snap.sum >= 100_100);
    assert!(snap.quantile_bound(1.0) >= 100_000, "p100 bound covers the max");
}

#[test]
fn histogram_snapshot_merge_models_peer_aggregation() {
    // what `union metrics --peers` does: merge per-peer snapshots
    let mut a = HistogramSnapshot { count: 2, sum: 5, buckets: vec![(1, 1), (3, 1)] };
    let b = HistogramSnapshot { count: 3, sum: 40, buckets: vec![(3, 2), (6, 1)] };
    a.merge(&b);
    assert_eq!(a.count, 5);
    assert_eq!(a.sum, 45);
    assert_eq!(a.buckets, vec![(1, 1), (3, 3), (6, 1)], "bucket-wise sum, index order");
    let empty = HistogramSnapshot::default();
    a.merge(&empty);
    assert_eq!(a.count, 5, "merging an idle peer is a no-op");
}

#[test]
fn engine_stats_absorb_adds_and_saturates() {
    let mut a = EngineStats {
        batches: 1,
        proposed: 10,
        scored: 8,
        cost_evals: 6,
        memo_hits: 2,
        memo_misses: 6,
        footprint_hits: 3,
        footprint_misses: 5,
        pruned: 1,
        rejected: 1,
    };
    let b = a.clone();
    a.absorb(&b);
    assert_eq!(
        a,
        EngineStats {
            batches: 2,
            proposed: 20,
            scored: 16,
            cost_evals: 12,
            memo_hits: 4,
            memo_misses: 12,
            footprint_hits: 6,
            footprint_misses: 10,
            pruned: 2,
            rejected: 2,
        },
        "plain absorb is field-wise addition"
    );

    // a session that has absorbed astronomically many jobs must pin at
    // the ceiling, never wrap to a small (and silently wrong) total
    let mut near_max = EngineStats { scored: usize::MAX - 3, ..EngineStats::default() };
    near_max.absorb(&EngineStats { scored: 10, ..EngineStats::default() });
    assert_eq!(near_max.scored, usize::MAX, "absorb saturates instead of wrapping");
    assert_eq!(near_max.batches, 0, "untouched fields stay exact");
    near_max.absorb(&EngineStats { scored: 1, ..EngineStats::default() });
    assert_eq!(near_max.scored, usize::MAX, "saturated fields stay pinned");
}

#[test]
fn metric_sources_emit_prefixed_stable_names() {
    let stats = EngineStats { scored: 11, pruned: 4, ..EngineStats::default() };
    let v = stats.metrics_vec();
    assert!(v.iter().all(|(n, _)| n.starts_with("engine_")), "prefix applied: {v:?}");
    let get = |name: &str| v.iter().find(|(n, _)| n == name).map(|&(_, x)| x);
    assert_eq!(get("engine_scored"), Some(11.0));
    assert_eq!(get("engine_pruned"), Some(4.0));
    assert_eq!(
        v.len(),
        10,
        "every EngineStats field is emitted — update the impl when fields change"
    );

    let cache = union::service::CacheStats::default();
    assert!(cache.metrics_vec().iter().all(|(n, _)| n.starts_with("cache_")));
    assert_eq!(cache.metrics_vec().len(), 10);

    let lru = union::util::lru::LruCache::<u8>::new(2, 64).stats();
    assert!(lru.metrics_vec().iter().all(|(n, _)| n.starts_with("lru_")));
}

#[test]
fn flight_recorder_is_bounded_with_ordered_replay() {
    let rec = FlightRecorder::with_capacity(4);
    assert_eq!(rec.len(), 0);
    for i in 0..10 {
        rec.record("test_event", &format!("i={i}"));
    }
    assert_eq!(rec.len(), 4, "ring stays at capacity");
    assert_eq!(rec.dropped(), 6, "displaced events are counted");
    assert_eq!(rec.latest_seq(), 10);

    // since() replays oldest-first, strictly after the cursor
    let all = rec.since(0, 100);
    assert_eq!(all.len(), 4);
    let seqs: Vec<u64> = all.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, vec![7, 8, 9, 10], "resident window, oldest first");
    assert!(all.windows(2).all(|w| w[0].t_us <= w[1].t_us), "timestamps are monotone");
    let after = rec.since(8, 100);
    assert_eq!(
        after.iter().map(|e| e.seq).collect::<Vec<_>>(),
        vec![9, 10],
        "cursor is exclusive"
    );
    let limited = rec.since(0, 2);
    assert_eq!(
        limited.iter().map(|e| e.seq).collect::<Vec<_>>(),
        vec![9, 10],
        "limit keeps the newest, still oldest-first"
    );
    assert_eq!(all[0].detail, "i=6");
    let line = all[0].to_jsonl();
    assert!(line.starts_with("{\"seq\":7,"), "JSONL leads with seq: {line}");
    assert!(line.contains("\"event\":\"test_event\""));
}

/// The tentpole acceptance pin: a search with telemetry recording
/// active (and the registry/recorder churning between runs) returns
/// **bit-identical** results to an identical search — telemetry is
/// observation only, it never perturbs sampling, pruning, or scoring.
#[test]
fn search_results_are_bit_identical_with_recording_active() {
    use union::arch::presets;
    use union::cost::{AnalyticalModel, EnergyTable};
    use union::mapspace::{Constraints, MapSpace};
    use union::problem::gemm;

    let arch = presets::edge();
    let cons = Constraints::default();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let problem = gemm(24, 32, 16);
    let space = MapSpace::new(&problem, &arch, &cons);

    let run = || {
        let mut session = Session::new(&model, Objective::Edp);
        let mut sources = vec![RandomMapper::new(300, 17).source()];
        let (r, stats) = session.run_job(&space, &mut sources);
        (r.expect("job finds a mapping"), stats)
    };

    let (first, first_stats) = run();
    // telemetry noise between runs: counters, histograms, flight events
    telemetry::counter("it_noise_total").add(1_000_000);
    for i in 0..2_000u64 {
        telemetry::histogram("it_noise_us").record(i * i);
    }
    for i in 0..64 {
        telemetry::event("test_event", &format!("noise {i}"));
    }
    let (second, second_stats) = run();

    assert_eq!(
        first.score.to_bits(),
        second.score.to_bits(),
        "score must be bit-identical under telemetry load"
    );
    assert_eq!(first.mapping, second.mapping, "winning mapping unchanged");
    assert_eq!(first.evaluated, second.evaluated);
    assert_eq!(first_stats, second_stats, "every engine counter repeats exactly");

    // and the spans actually recorded: two jobs ran above, so the
    // per-phase histograms hold at least two observations each
    let hists = telemetry::registry().histogram_snapshots();
    for phase in ["sample", "memo", "evaluate", "prune"] {
        let name = format!("engine_phase_{phase}_us");
        let (_, snap) = hists
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("{name} missing from registry"));
        assert!(snap.count >= 2, "{name} recorded {} < 2 observations", snap.count);
    }
}

#[test]
fn broker_stats_merge_arithmetic_is_exact() {
    use union::service::BrokerStats;
    let mut total = BrokerStats::default();
    let mut shard = BrokerStats::default();
    shard.requests = 5;
    shard.cache_hits = 2;
    shard.searched = 3;
    shard.engine.scored = 120;
    total.requests += shard.requests;
    total.cache_hits += shard.cache_hits;
    total.searched += shard.searched;
    total.engine.absorb(&shard.engine);
    // a second fold of the same shard must not be hidden by the merge —
    // the broker's drain() idempotence test pins that stats() itself
    // never double-folds; here we pin the arithmetic building block
    total.engine.absorb(&shard.engine);
    assert_eq!(total.engine.scored, 240);
    let v = total.metrics_vec();
    let get = |name: &str| v.iter().find(|(n, _)| n == name).map(|&(_, x)| x);
    assert_eq!(get("broker_requests"), Some(5.0));
    assert_eq!(get("broker_cache_hits"), Some(2.0));
    assert_eq!(get("broker_searched"), Some(3.0));
}
