//! Integration tests for the mapping service: coalescing, the
//! persistent cache (round trip + corruption tolerance), canonical
//! job-signature stability, and the TCP protocol end to end.

use std::path::PathBuf;

use union::arch::presets;
use union::engine::EngineStats;
use union::frontend::Workload;
use union::mappers::Objective;
use union::mapspace::Constraints;
use union::service::{
    client_request, client_request_with, job_signature, Broker, BrokerConfig, CostKind,
    JobRequest, JobSpec, Json, Request, ResultCache, ServeConfig, Server, Submitted,
};
use union::util::quickcheck::QuickCheck;

fn tmp_path(tag: &str) -> PathBuf {
    static NEXT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "union-service-test-{}-{tag}-{n}.jsonl",
        std::process::id()
    ))
}

fn gemm_job(m: u64, n: u64, k: u64, samples: usize, seed: u64) -> JobRequest {
    JobRequest {
        workload: Workload::gemm(&format!("gemm:{m}x{n}x{k}"), m, n, k),
        arch: presets::edge(),
        cost: CostKind::Analytical,
        objective: Objective::Edp,
        constraints: Constraints::default(),
        samples,
        seed,
    }
}

// ---------------------------------------------------------------------------
// coalescing
// ---------------------------------------------------------------------------

/// Acceptance criterion: concurrent identical requests coalesce onto
/// ONE search. A paused broker makes the concurrency deterministic:
/// all submissions land before any worker runs.
#[test]
fn concurrent_identical_requests_coalesce_to_one_search() {
    let broker = Broker::new(BrokerConfig {
        shards: 2,
        paused: true,
        ..BrokerConfig::default()
    });
    const WAITERS: usize = 6;
    let mut rxs = Vec::new();
    for _ in 0..WAITERS {
        match broker.submit(gemm_job(32, 32, 32, 200, 42)) {
            Submitted::Pending { rx, coalesced, .. } => rxs.push((rx, coalesced)),
            other => panic!("expected pending, got {}", kind(&other)),
        }
    }
    assert_eq!(
        rxs.iter().filter(|(_, c)| *c).count(),
        WAITERS - 1,
        "all but the first submission coalesce"
    );
    broker.resume();
    let results: Vec<_> = rxs
        .into_iter()
        .map(|(rx, _)| rx.recv().expect("job answered").result.expect("job succeeded"))
        .collect();
    for r in &results[1..] {
        assert_eq!(r, &results[0], "every waiter sees the identical result");
        assert_eq!(r.score.to_bits(), results[0].score.to_bits());
    }
    let stats = broker.drain();
    assert_eq!(stats.requests, WAITERS);
    assert_eq!(stats.searched, 1, "exactly one engine search ran");
    assert_eq!(stats.coalesced, WAITERS - 1);
    assert_eq!(stats.cache_hits, 0);
    // the engine did the work of ONE portfolio search, not six:
    // engine counters are deterministic, so they must equal a fresh
    // broker's counters for a single submission of the same job
    let solo = Broker::new(BrokerConfig { shards: 2, ..BrokerConfig::default() });
    solo.submit_wait(gemm_job(32, 32, 32, 200, 42)).unwrap();
    let solo_stats = solo.drain();
    assert!(stats.engine.scored > 0);
    assert_eq!(stats.engine, solo_stats.engine, "coalesced run did extra engine work");
}

fn kind(s: &Submitted) -> &'static str {
    match s {
        Submitted::Cached(_) => "cached",
        Submitted::Pending { .. } => "pending",
        Submitted::Overloaded { .. } => "overloaded",
        Submitted::Draining => "draining",
        Submitted::Rejected(_) => "rejected",
    }
}

// ---------------------------------------------------------------------------
// persistent cache
// ---------------------------------------------------------------------------

/// Acceptance criterion: a second run of the same job — in a NEW broker
/// over the same cache file, as after a daemon restart — is served from
/// the persistent cache with a bit-identical result and no engine work.
#[test]
fn second_run_is_served_from_persistent_cache_bit_identically() {
    let path = tmp_path("roundtrip");
    let job = || gemm_job(48, 24, 96, 180, 7);

    let first = {
        let broker =
            Broker::with_cache(BrokerConfig::default(), ResultCache::open(&path).unwrap());
        let r = broker.submit_wait(job()).expect("first run searches");
        let stats = broker.drain();
        assert_eq!(stats.searched, 1);
        assert!(stats.engine.scored > 0);
        r
    };

    // "another process": a fresh broker loads the cache from disk
    let broker =
        Broker::with_cache(BrokerConfig::default(), ResultCache::open(&path).unwrap());
    let second = match broker.submit(job()) {
        Submitted::Cached(hit) => *hit,
        other => panic!("expected a cache hit, got {}", kind(&other)),
    };
    assert_eq!(second, first);
    assert_eq!(second.score.to_bits(), first.score.to_bits(), "bit-identical score");
    assert_eq!(second.cycles.to_bits(), first.cycles.to_bits());
    assert_eq!(second.mapping, first.mapping);
    let stats = broker.drain();
    assert_eq!(stats.searched, 0, "no engine work on the cached path");
    assert_eq!(stats.cache_hits, 1);
    assert_eq!(stats.engine, EngineStats::default(), "engine untouched");
    std::fs::remove_file(&path).ok();
}

/// A truncated/corrupted cache file must load what it can and never
/// panic — bad records are skipped and counted, and the store keeps
/// accepting appends afterwards.
#[test]
fn corrupted_cache_file_skips_bad_records_without_panicking() {
    let path = tmp_path("corrupt");
    {
        let broker =
            Broker::with_cache(BrokerConfig::default(), ResultCache::open(&path).unwrap());
        broker.submit_wait(gemm_job(16, 16, 16, 60, 1)).unwrap();
        broker.submit_wait(gemm_job(24, 8, 8, 60, 1)).unwrap();
    }
    // corrupt the file: garbage line, malformed record, truncated tail
    let mut text = std::fs::read_to_string(&path).unwrap();
    text.push_str("not json at all\n");
    text.push_str("{\"sig\":\"orphan\",\"score\":1.5}\n");
    text.push_str("{\"sig\":\"truncated\",\"score\":2.5,\"mapping\":[[[0],[1");
    std::fs::write(&path, &text).unwrap();

    let cache = ResultCache::open(&path).unwrap();
    assert_eq!(cache.len(), 2, "both good records survive");
    assert_eq!(cache.stats().loaded, 2);
    assert_eq!(cache.stats().skipped, 3, "all three bad lines skipped");

    // and the store still serves + accepts appends
    let broker = Broker::with_cache(BrokerConfig::default(), cache);
    assert!(matches!(
        broker.submit(gemm_job(16, 16, 16, 60, 1)),
        Submitted::Cached(_)
    ));
    broker.submit_wait(gemm_job(40, 8, 8, 60, 1)).unwrap();
    // flushes are batched now; force one so the append is visible
    broker.flush_cache();
    let (entries, stats) = broker.cache_stats();
    assert_eq!(entries, 3);
    assert_eq!(stats.appended, 1);
    drop(broker);

    // the record appended after the truncated tail must survive a
    // reopen: open() repairs the missing newline so the new record is
    // not fused onto the garbage line
    let reloaded = ResultCache::open(&path).unwrap();
    assert_eq!(reloaded.len(), 3, "append-after-truncation record was lost");
    let broker = Broker::with_cache(BrokerConfig::default(), reloaded);
    assert!(matches!(
        broker.submit(gemm_job(40, 8, 8, 60, 1)),
        Submitted::Cached(_)
    ));
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// job-signature stability
// ---------------------------------------------------------------------------

/// Property: the canonical signature — the persistent-cache key — is a
/// pure function of the request. It must not move with the broker's
/// thread count, the process's hash seeds (no `DefaultHasher`, no map
/// iteration), or the workload's display name; and distinct search
/// parameters must produce distinct signatures.
#[test]
fn prop_job_signature_is_stable_and_canonical() {
    QuickCheck::new().cases(150).seed(0x5E2F1CE).check("signature-stable", |g| {
        let m = 1 + g.dim();
        let n = 1 + g.dim();
        let k = 1 + g.dim();
        let samples = 10 + g.range(0, 500);
        let seed = g.rng().next_u64();
        let job = gemm_job(m, n, k, samples, seed);
        let sig = job_signature(&job);

        // deterministic across repeated computation and across clones
        // (a fresh parse of the same spec in another process hits the
        // same code path: nothing ambient feeds the signature)
        if sig != job_signature(&job.clone()) {
            return Err("signature not deterministic".into());
        }
        // computing it on another thread changes nothing
        let job2 = job.clone();
        let from_thread =
            std::thread::spawn(move || job_signature(&job2)).join().unwrap();
        if sig != from_thread {
            return Err("signature differs across threads".into());
        }
        // name-independent: renaming the workload keeps the identity
        let mut renamed = job.clone();
        renamed.workload.name = format!("renamed-{m}");
        if sig != job_signature(&renamed) {
            return Err("workload name leaked into the signature".into());
        }
        // parameter changes change the identity
        let mut other = job.clone();
        other.seed = seed.wrapping_add(1);
        if sig == job_signature(&other) {
            return Err("seed not part of the signature".into());
        }
        // cache-record safe: single line
        if sig.contains('\n') {
            return Err("signature contains a newline".into());
        }
        Ok(())
    });
}

/// The signature string itself is pinned: an accidental format change
/// would orphan every persistent cache in the field. Bump the version
/// tag (and this test) when changing it deliberately.
#[test]
fn job_signature_format_is_pinned() {
    let sig = job_signature(&gemm_job(32, 16, 8, 100, 42));
    assert!(sig.starts_with("union-job-v1|"), "{sig}");
    for field in ["|arch=edge#", "|model=analytical|", "|obj=EDP|", "|samples=100|", "|seed=42"] {
        assert!(sig.contains(field), "missing {field} in {sig}");
    }
    // the parameterized sparse kind carries its full configuration into
    // the signature (densities and metadata overheads must never
    // coalesce across configs), while the dense kinds keep the exact
    // strings above — so caches written before CostKind learned
    // parameters still hit
    let mut sparse = gemm_job(32, 16, 8, 100, 42);
    sparse.cost = CostKind::sparse_analytical(0.1, 0.05).unwrap();
    let ssig = job_signature(&sparse);
    assert!(ssig.contains("|model=sparse-analytical:d=0.1,meta=0.05|"), "{ssig}");
}

/// Differently-configured sparse jobs are distinct cache/coalescing
/// identities: any change to density or metadata overhead must change
/// the signature.
#[test]
fn sparse_job_signatures_key_density_and_metadata() {
    let base = gemm_job(32, 32, 32, 100, 42);
    let with = |d: f64, meta: f64| {
        let mut req = base.clone();
        req.cost = CostKind::sparse_analytical(d, meta).unwrap();
        job_signature(&req)
    };
    let a = with(0.1, 0.05);
    assert_ne!(a, job_signature(&base), "sparse must not collide with dense");
    assert_ne!(a, with(0.5, 0.05), "density keys the signature");
    assert_ne!(a, with(0.1, 0.10), "metadata overhead keys the signature");
    assert_eq!(a, with(0.1, 0.05), "same config, same identity");
}

/// Identical jobs route to the same shard (signature-hash routing), so
/// repeat traffic lands on the session that is already warm for it.
#[test]
fn identical_jobs_route_to_one_shard() {
    let broker = Broker::new(BrokerConfig {
        shards: 4,
        paused: true,
        ..BrokerConfig::default()
    });
    let mut shards = Vec::new();
    for _ in 0..3 {
        match broker.submit(gemm_job(64, 32, 16, 50, 9)) {
            Submitted::Pending { shard, .. } => shards.push(shard),
            other => panic!("expected pending, got {}", kind(&other)),
        }
    }
    assert!(shards.windows(2).all(|w| w[0] == w[1]), "{shards:?}");
    broker.resume();
    broker.drain();
}

// ---------------------------------------------------------------------------
// TCP end to end
// ---------------------------------------------------------------------------

fn search_spec(workload: &str, samples: usize, seed: u64) -> JobSpec {
    JobSpec {
        workload: workload.into(),
        arch: "edge".into(),
        cost: "analytical".into(),
        objective: Objective::Edp,
        samples,
        seed,
        constraints: String::new(),
    }
}

#[test]
fn tcp_server_serves_search_status_and_drains_on_shutdown() {
    let server = Server::bind(ServeConfig {
        port: 0, // ephemeral
        broker: BrokerConfig { shards: 2, ..BrokerConfig::default() },
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let daemon = std::thread::spawn(move || server.run());

    // search twice: fresh, then served from the (in-memory) cache
    let req = Request::Search {
        id: Some("a".into()),
        spec: search_spec("gemm:32x32x32", 120, 3),
        progress: false,
    };
    let first = client_request(&addr, &req).unwrap();
    assert_eq!(first.str("type"), Some("result"), "{}", first.to_line());
    assert_eq!(first.str("id"), Some("a"));
    assert_eq!(first.bool_field("cached"), Some(false));
    let second = client_request(&addr, &req).unwrap();
    assert_eq!(second.bool_field("cached"), Some(true));
    assert_eq!(
        second.num("score").unwrap().to_bits(),
        first.num("score").unwrap().to_bits(),
        "cached answer is bit-identical over the wire"
    );

    // a malformed and an unknown-workload request answer in-band
    let bad = client_request(&addr, &Request::Search {
        id: Some("b".into()),
        spec: search_spec("warpdrive", 10, 1),
        progress: false,
    })
    .unwrap();
    assert_eq!(bad.str("type"), Some("error"));
    assert_eq!(bad.str("id"), Some("b"));

    let status = client_request(&addr, &Request::Status { id: None }).unwrap();
    assert_eq!(status.str("type"), Some("status"));
    assert_eq!(status.num("searched"), Some(1.0));
    assert_eq!(status.num("cache_hits"), Some(1.0));

    let bye = client_request(&addr, &Request::Shutdown { id: Some("z".into()) }).unwrap();
    assert_eq!(bye.str("type"), Some("shutdown"));
    assert_eq!(bye.bool_field("ok"), Some(true));
    let stats = daemon.join().unwrap().unwrap();
    assert_eq!(stats.searched, 1);

    // the daemon is really gone
    assert!(client_request(&addr, &Request::Status { id: None }).is_err());
}

#[test]
fn tcp_search_equals_direct_orchestrator_run() {
    // the service answer must be byte-identical to running the same job
    // locally (what CI's service smoke test asserts via the CLI)
    let server = Server::bind(ServeConfig { port: 0, ..ServeConfig::default() }).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let daemon = std::thread::spawn(move || server.run());

    let spec = search_spec("gemm:64x16x32", 150, 11);
    let served = client_request(
        &addr,
        &Request::Search { id: None, spec: spec.clone(), progress: false },
    )
    .unwrap();
    let mapping = union::service::mapping_from_json(served.get("mapping").unwrap()).unwrap();

    let job = union::service::resolve_spec(&spec).unwrap();
    let direct = {
        use union::network::{NetworkOrchestrator, OrchestratorConfig, WorkloadGraph};
        let graph = WorkloadGraph::from_workloads("direct", vec![job.workload.clone()]);
        let orch = NetworkOrchestrator::with_config(
            &job.arch,
            job.cost.model(),
            &job.constraints,
            OrchestratorConfig {
                objective: job.objective,
                samples: job.samples,
                seed: job.seed,
                threads: Some(1),
            },
        );
        orch.run(&graph).unwrap()
    };
    let direct_best = &direct.layers[0].result;
    assert_eq!(mapping, direct_best.mapping, "service and direct search disagree");
    assert_eq!(
        served.num("score").unwrap().to_bits(),
        direct_best.score.to_bits(),
        "scores must be bit-identical"
    );

    client_request(&addr, &Request::Shutdown { id: None }).unwrap();
    daemon.join().unwrap().unwrap();
}

#[test]
fn backpressure_overloaded_response_reaches_the_wire() {
    // 1 shard, queue depth 1, paused workers: the first distinct job
    // parks in the queue, a second distinct job must bounce with an
    // explicit `overloaded` response (not an error, not a hang). Submit
    // straight through the broker handle embedded in a stdio-style
    // handler to keep the worker gate deterministic.
    let broker = Broker::new(BrokerConfig {
        shards: 1,
        queue_capacity: 1,
        paused: true,
        ..BrokerConfig::default()
    });
    let parked = broker.submit(gemm_job(32, 32, 32, 40, 5));
    assert!(matches!(parked, Submitted::Pending { .. }));
    let (resp, stop) = union::service::server::handle_line(
        &broker,
        &Request::Search {
            id: Some("x".into()),
            spec: search_spec("gemm:16x8x8", 40, 5),
            progress: false,
        }
        .to_line(),
    );
    assert!(!stop);
    assert_eq!(resp.str("type"), Some("overloaded"), "{}", resp.to_line());
    assert_eq!(resp.bool_field("ok"), Some(false));
    assert_eq!(resp.str("id"), Some("x"));
    broker.resume();
    if let Submitted::Pending { rx, .. } = parked {
        rx.recv().unwrap().result.unwrap();
    }
    let stats = broker.drain();
    assert_eq!(stats.overloaded, 1);
}

/// Acceptance criterion: the reactor multiplexes every connection on
/// ONE thread. Idle and slow-reading clients cost buffers, not threads,
/// and never wedge the accept loop — asserted via the server-side
/// `conn_threads_spawned` counter, which must stay zero in steady
/// state.
#[test]
fn reactor_serves_concurrent_clients_with_zero_connection_threads() {
    let server = Server::bind(ServeConfig {
        port: 0,
        broker: BrokerConfig { shards: 2, ..BrokerConfig::default() },
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stats = server.stats_handle();
    let daemon = std::thread::spawn(move || server.run());

    // an idle connection that never sends a byte: it must not block
    // later accepts or responses
    let idle = std::net::TcpStream::connect(&addr).unwrap();

    const CLIENTS: usize = 6;
    let workers: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                client_request(&addr, &Request::Search {
                    id: Some(format!("c{i}")),
                    spec: search_spec("gemm:24x24x24", 80, 2),
                    progress: false,
                })
                .unwrap()
            })
        })
        .collect();
    let results: Vec<Json> = workers.into_iter().map(|w| w.join().unwrap()).collect();
    for r in &results {
        assert_eq!(r.str("type"), Some("result"), "{}", r.to_line());
        assert_eq!(
            r.num("score").unwrap().to_bits(),
            results[0].num("score").unwrap().to_bits(),
            "identical concurrent jobs must answer identically"
        );
    }

    // a slow reader: submits a request and never reads the response;
    // the reactor must keep answering everyone else regardless
    {
        use std::io::Write;
        let mut slow = std::net::TcpStream::connect(&addr).unwrap();
        let line = Request::Search {
            id: Some("slow".into()),
            spec: search_spec("gemm:24x24x24", 80, 2),
            progress: false,
        }
        .to_line();
        writeln!(slow, "{line}").unwrap();
        let status = client_request(&addr, &Request::Status { id: None }).unwrap();
        assert_eq!(status.str("type"), Some("status"));
    }

    assert!(stats.accepted() >= (CLIENTS as u64) + 2, "accepted {}", stats.accepted());
    assert_eq!(
        stats.conn_threads_spawned(),
        0,
        "the reactor must never spawn a per-connection thread"
    );
    drop(idle);
    client_request(&addr, &Request::Shutdown { id: None }).unwrap();
    daemon.join().unwrap().unwrap();
}

/// Pipelined requests on ONE connection answer strictly in request
/// order, even when a later request (status) could finish before an
/// earlier search.
#[test]
fn pipelined_requests_on_one_connection_answer_in_order() {
    use std::io::{BufRead, BufReader, Write};

    let server = Server::bind(ServeConfig { port: 0, ..ServeConfig::default() }).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let daemon = std::thread::spawn(move || server.run());

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut batch = String::new();
    batch.push_str(
        &Request::Search {
            id: Some("r1".into()),
            spec: search_spec("gemm:16x16x32", 90, 4),
            progress: false,
        }
        .to_line(),
    );
    batch.push('\n');
    batch.push_str(&Request::Status { id: Some("r2".into()) }.to_line());
    batch.push('\n');
    // identical to r1: coalesces with it or hits the cache, but must
    // still answer third
    batch.push_str(
        &Request::Search {
            id: Some("r3".into()),
            spec: search_spec("gemm:16x16x32", 90, 4),
            progress: false,
        }
        .to_line(),
    );
    batch.push('\n');
    stream.write_all(batch.as_bytes()).unwrap();

    let mut reader = BufReader::new(stream);
    let mut read_one = || {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    let r1 = read_one();
    let r2 = read_one();
    let r3 = read_one();
    assert_eq!(r1.str("id"), Some("r1"), "{}", r1.to_line());
    assert_eq!(r1.str("type"), Some("result"));
    assert_eq!(r2.str("id"), Some("r2"), "{}", r2.to_line());
    assert_eq!(r2.str("type"), Some("status"));
    assert_eq!(r3.str("id"), Some("r3"), "{}", r3.to_line());
    assert_eq!(r3.str("type"), Some("result"));
    assert_eq!(
        r3.num("score").unwrap().to_bits(),
        r1.num("score").unwrap().to_bits(),
        "pipelined duplicate must answer bit-identically"
    );

    client_request(&addr, &Request::Shutdown { id: None }).unwrap();
    daemon.join().unwrap().unwrap();
}

/// Anytime progress: a streaming search interleaves `progress` events
/// before its final `result` on the same connection, and streaming
/// never perturbs the answer — a plain replay is cached and
/// bit-identical.
#[test]
fn streamed_progress_precedes_final_result_on_the_wire() {
    let server = Server::bind(ServeConfig { port: 0, ..ServeConfig::default() }).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let daemon = std::thread::spawn(move || server.run());

    let spec = search_spec("gemm:48x16x16", 400, 13);
    let mut events: Vec<Json> = Vec::new();
    let streamed = client_request_with(
        &addr,
        &Request::Search { id: Some("p".into()), spec: spec.clone(), progress: true },
        &mut |ev| events.push(ev.clone()),
    )
    .unwrap();
    assert_eq!(streamed.str("type"), Some("result"), "{}", streamed.to_line());
    assert_eq!(streamed.str("id"), Some("p"));
    assert!(!events.is_empty(), "a 400-sample search must report progress");
    let sig = streamed.str("signature").unwrap();
    let mut last_eval = 0.0;
    for ev in &events {
        assert_eq!(ev.str("type"), Some("progress"), "{}", ev.to_line());
        assert_eq!(ev.str("id"), Some("p"));
        assert_eq!(ev.str("signature"), Some(sig), "event for the wrong job");
        let eval = ev.num("evaluated").unwrap();
        assert!(eval >= last_eval, "evaluated count went backwards");
        last_eval = eval;
    }
    assert!(
        events.iter().any(|ev| ev.num("best_score").is_some()),
        "at least one snapshot carries a best-so-far score"
    );

    let replay = client_request(
        &addr,
        &Request::Search { id: None, spec, progress: false },
    )
    .unwrap();
    assert_eq!(replay.bool_field("cached"), Some(true));
    assert_eq!(
        replay.num("score").unwrap().to_bits(),
        streamed.num("score").unwrap().to_bits(),
        "streaming must not perturb the result"
    );

    client_request(&addr, &Request::Shutdown { id: None }).unwrap();
    daemon.join().unwrap().unwrap();
}

#[test]
fn json_response_parses_with_plain_parser() {
    // belt and braces: every response the server writes must be valid
    // single-line JSON (protocol framing), including escaped text
    let broker = Broker::new(BrokerConfig { shards: 1, ..BrokerConfig::default() });
    let (resp, _) = union::service::server::handle_line(&broker, "{\"type\":\"status\"}");
    let line = resp.to_line();
    assert!(!line.contains('\n'));
    assert_eq!(Json::parse(&line).unwrap(), resp);
    broker.drain();
}
