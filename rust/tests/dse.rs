//! Design-space-exploration contracts: Pareto-frontier properties
//! (non-domination, insertion-order invariance, dominated inserts are
//! no-ops) and whole-sweep thread-count determinism, mirroring
//! `tests/engine_determinism.rs`.

use union::cost::{AnalyticalModel, EnergyTable};
use union::dse::{dominates, DseConfig, DseOrchestrator, GridSpaceBuilder, ParetoFrontier};
use union::frontend;
use union::mapspace::Constraints;
use union::util::quickcheck::{Gen, QuickCheck};

/// Random 3-objective points on a small integer grid, so duplicates and
/// dominance chains are common.
fn random_points(g: &mut Gen, n: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..3).map(|_| g.range(1, 9) as f64).collect())
        .collect()
}

fn build(points: &[Vec<f64>]) -> ParetoFrontier {
    let mut f = ParetoFrontier::new(3);
    for (i, p) in points.iter().enumerate() {
        f.insert(p, i);
    }
    f
}

/// The frontier's objective vectors (stored lexicographically sorted,
/// so two frontiers over the same set compare with `==`).
fn objective_set(f: &ParetoFrontier) -> Vec<Vec<f64>> {
    f.points().iter().map(|(p, _)| p.clone()).collect()
}

#[test]
fn every_reported_point_is_non_dominated() {
    QuickCheck::new().cases(200).check("mutually-non-dominated", |g| {
        let n = g.range(1, 24);
        let pts = random_points(g, n);
        let objs = objective_set(&build(&pts));
        for i in 0..objs.len() {
            for j in 0..objs.len() {
                if i != j && dominates(&objs[i], &objs[j]) {
                    return Err(format!("{:?} dominates {:?}", objs[i], objs[j]));
                }
            }
        }
        // and every input point is covered by some frontier point
        for p in &pts {
            if !objs.iter().any(|q| dominates(q, p)) {
                return Err(format!("{p:?} not covered by the frontier"));
            }
        }
        Ok(())
    });
}

#[test]
fn inserting_a_dominated_point_never_changes_the_frontier() {
    QuickCheck::new().cases(200).check("dominated-insert-is-noop", |g| {
        let n = g.range(1, 20);
        let pts = random_points(g, n);
        let mut f = build(&pts);
        let before = objective_set(&f);
        // worsen a random input point along random axes (zero delta
        // included: exact duplicates are dominated too)
        let base = pts[g.range(0, n - 1)].clone();
        let worse: Vec<f64> = base.iter().map(|v| v + g.range(0, 3) as f64).collect();
        if f.insert(&worse, usize::MAX) {
            return Err(format!("dominated point {worse:?} entered the frontier"));
        }
        if objective_set(&f) != before {
            return Err("frontier changed on a dominated insert".to_string());
        }
        Ok(())
    });
}

#[test]
fn frontier_is_invariant_to_insertion_order() {
    QuickCheck::new().cases(200).check("order-invariant", |g| {
        let n = g.range(1, 20);
        let mut pts = random_points(g, n);
        let a = objective_set(&build(&pts));
        g.rng().shuffle(&mut pts);
        let b = objective_set(&build(&pts));
        if a != b {
            return Err(format!("order changed the frontier: {a:?} vs {b:?}"));
        }
        Ok(())
    });
}

#[test]
fn dse_sweep_is_thread_count_invariant() {
    // the whole DSE pipeline (bounds -> dominance skips -> shared
    // session with warm starts -> frontier) must inherit the engine's
    // determinism: byte-identical reports at 1 and N threads
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let space = GridSpaceBuilder::new("det")
        .grids(&[(4, 4), (8, 8), (16, 16)])
        .l2_bytes(&[64 * 1024, 512 * 1024])
        .build();
    let graph = frontend::dlrm_layers();
    let run = |threads: Option<usize>| {
        let config = DseConfig {
            samples: 120,
            seed: 13,
            threads,
            ..DseConfig::default()
        };
        DseOrchestrator::with_config(&model, &cons, config)
            .run(&space, &graph)
            .expect("sweep runs")
    };
    let r1 = run(Some(1));
    let rn = run(Some(8));
    assert_eq!(r1.stats.evaluated, rn.stats.evaluated);
    assert_eq!(r1.stats.pruned, rn.stats.pruned);
    assert_eq!(r1.stats.engine, rn.stats.engine, "engine stats depend on threads");
    // the strongest form: the rendered artifacts are byte-identical
    assert_eq!(
        r1.points_table().render(),
        rn.points_table().render(),
        "DSE points table depends on thread count"
    );
    assert_eq!(
        r1.frontier_table().render(),
        rn.frontier_table().render(),
        "DSE frontier depends on thread count"
    );
    assert_eq!(r1.summary(), rn.summary());
}

#[test]
fn dse_sweep_is_reproducible_across_runs() {
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let space = GridSpaceBuilder::new("repro")
        .grids(&[(4, 4), (8, 8)])
        .l2_bytes(&[128 * 1024])
        .build();
    let graph = frontend::dlrm_layers();
    let run = || {
        let config = DseConfig { samples: 100, seed: 7, ..DseConfig::default() };
        DseOrchestrator::with_config(&model, &cons, config)
            .run(&space, &graph)
            .expect("sweep runs")
    };
    assert_eq!(run().points_table().render(), run().points_table().render());
}
