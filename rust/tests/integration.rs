//! Integration: figure drivers produce the paper's qualitative shapes,
//! and the CLI surface parses and dispatches correctly.

use union::experiments::{
    fig3_mapping_sweep, fig8_algorithm_exploration, table3_ttgt_dims, Effort,
};

#[test]
fn table3_regenerates_exactly() {
    let t = table3_ttgt_dims();
    let csv = t.to_csv();
    for needle in [
        "intensli2,dbea,ec->abcd".replace(',', ""), // spot-check content exists
    ] {
        let _ = needle;
    }
    assert!(csv.contains("262144,64,64"));
    assert!(csv.contains("32768,32768,32"));
    assert!(csv.contains("256,16,256"));
}

#[test]
fn fig3_spread_is_paper_scale() {
    let (_, raw) = fig3_mapping_sweep(Effort::Fast);
    assert!(raw.len() >= 6);
    let edps: Vec<f64> = raw.iter().map(|r| r.2).collect();
    let spread = edps.iter().copied().fold(f64::MIN, f64::max)
        / edps.iter().copied().fold(f64::MAX, f64::min);
    // the paper's Fig. 3 shows order-of-magnitude spreads across mappings
    assert!(spread > 5.0, "EDP spread {spread} too small for Fig 3's story");
}

#[test]
fn fig8_ttgt_wins_small_tds() {
    let (_, points) = fig8_algorithm_exploration(Effort::Fast);
    assert_eq!(points.len(), 6);
    for p in points.iter().filter(|p| p.tds == 16) {
        assert!(
            p.ttgt_edp < p.native_edp,
            "{}: TTGT must win at TDS=16 (native {:.3e}, ttgt {:.3e})",
            p.problem,
            p.native_edp,
            p.ttgt_edp
        );
        // root cause per the paper: native under-utilizes the 32x64 array
        assert!(
            p.native_util < p.ttgt_util,
            "{}: native util {} should trail TTGT util {}",
            p.problem,
            p.native_util,
            p.ttgt_util
        );
    }
}

#[test]
fn cli_arg_surface() {
    use union::cli::{parse_arch, parse_workload, Args};
    let a = Args::parse(
        "search --workload tc:intensli2:16 --arch cloud:32x64 --mapper genetic --render"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    assert_eq!(a.subcommand.as_deref(), Some("search"));
    assert!(parse_workload(a.flag("workload").unwrap()).is_ok());
    assert!(parse_arch(a.flag("arch").unwrap()).is_ok());
    assert!(a.switch("render"));
}

#[test]
fn report_layer_round_trips_figures() {
    let (table, _) = fig3_mapping_sweep(Effort::Fast);
    let text = table.render();
    assert!(text.contains("norm EDP"));
    let csv = table.to_csv();
    assert_eq!(csv.lines().count(), table.rows.len() + 1);
}
