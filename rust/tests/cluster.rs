//! End-to-end tests for the multi-process serving layer: rendezvous
//! routing with failover, `sync` cache shipping between live peers,
//! corruption/version handling on import, and the `union router`
//! proxy. The pure rendezvous-hash properties (permutation
//! invariance, minimal re-keying, ~1/N steal) live as property tests
//! inside `service/cluster.rs`; these tests exercise real sockets.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::thread;

use union::mappers::Objective;
use union::service::{
    client_request, job_signature, mapping_from_json, resolve_spec, sync_from_peer,
    BrokerConfig, BrokerStats, Cluster, ClusterClient, JobSpec, Request, ResultCache, Router,
    RouterConfig, ServeConfig, Server, CACHE_VERSION,
};

fn tmp_path(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!(
        "union-cluster-{tag}-{}-{:?}",
        std::process::id(),
        thread::current().id()
    ));
    p
}

fn search_spec(workload: &str, samples: usize, seed: u64) -> JobSpec {
    JobSpec {
        workload: workload.into(),
        arch: "edge".into(),
        cost: "analytical".into(),
        objective: Objective::Edp,
        samples,
        seed,
        constraints: String::new(),
    }
}

type Daemon = thread::JoinHandle<Result<BrokerStats, String>>;

fn start_server(cache: Option<PathBuf>) -> (String, Daemon) {
    let server = Server::bind(ServeConfig {
        port: 0,
        cache,
        broker: BrokerConfig { shards: 2, ..BrokerConfig::default() },
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let daemon = thread::spawn(move || server.run());
    (addr, daemon)
}

fn shutdown(addr: &str, daemon: Daemon) -> BrokerStats {
    client_request(addr, &Request::Shutdown { id: None }).unwrap();
    daemon.join().unwrap().unwrap()
}

/// An address that accepts nothing: bind an ephemeral listener, note
/// its port, drop it. Connections to it fail fast with refused.
fn dead_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    addr
}

#[test]
fn sync_ships_cache_between_peers_bit_identically() {
    let (addr, daemon) = start_server(None);
    let specs = [search_spec("gemm:16x16x16", 60, 7), search_spec("gemm:24x16x8", 60, 9)];
    let mut served = Vec::new();
    for spec in &specs {
        let doc = client_request(
            &addr,
            &Request::Search { id: None, spec: spec.clone(), progress: false },
        )
        .unwrap();
        assert_eq!(doc.str("type"), Some("result"), "{}", doc.to_line());
        served.push(doc);
    }

    // a fresh peer warms itself entirely from the snapshot
    let mut local = ResultCache::in_memory();
    let stats = sync_from_peer(&addr, &mut local).unwrap();
    assert_eq!(stats.received, 2);
    assert_eq!(stats.imported, 2);
    assert_eq!((stats.duplicates, stats.skipped), (0, 0));
    assert_eq!(local.len(), 2);
    for (spec, doc) in specs.iter().zip(&served) {
        let sig = job_signature(&resolve_spec(spec).unwrap());
        let record = local.get(&sig).expect("synced record present");
        assert_eq!(
            record.score.to_bits(),
            doc.num("score").unwrap().to_bits(),
            "shipped record must be bit-identical to the served result"
        );
        let served_mapping = mapping_from_json(doc.get("mapping").unwrap()).unwrap();
        assert_eq!(record.mapping, served_mapping);
    }

    // re-sync is idempotent: everything is a duplicate, nothing changes
    let again = sync_from_peer(&addr, &mut local).unwrap();
    assert_eq!(again.imported, 0);
    assert_eq!(again.duplicates, 2);
    assert_eq!(local.len(), 2);

    shutdown(&addr, daemon);
}

/// A scripted peer that answers one `sync` with exactly the given
/// header version and record lines (optionally dropping the
/// connection without a trailer).
fn fake_sync_peer(version: u64, lines: Vec<String>, send_end: bool) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // the sync request
        let mut w = stream;
        writeln!(
            w,
            "{{\"type\":\"sync\",\"ok\":true,\"version\":{version},\"records\":{}}}",
            lines.len()
        )
        .unwrap();
        for l in &lines {
            writeln!(w, "{l}").unwrap();
        }
        if send_end {
            writeln!(w, "{{\"type\":\"sync_end\",\"ok\":true,\"records\":{}}}", lines.len())
                .unwrap();
        }
    });
    addr
}

#[test]
fn sync_rejects_version_mismatch_before_any_import() {
    let addr = fake_sync_peer(99, vec!["{\"sig\":\"x\"}".into()], true);
    let mut cache = ResultCache::in_memory();
    let err = sync_from_peer(&addr, &mut cache).unwrap_err();
    assert!(err.contains("version 99"), "unexpected error: {err}");
    assert_eq!(cache.len(), 0, "no record may land from a rejected snapshot");
}

#[test]
fn sync_skips_corrupt_records_without_panicking() {
    let addr = fake_sync_peer(
        CACHE_VERSION,
        vec![
            "this is not json".into(),
            "{\"sig\":\"x\"}".into(), // parseable but structurally broken
            String::new(),            // blank line: ignored entirely
        ],
        true,
    );
    let mut cache = ResultCache::in_memory();
    let stats = sync_from_peer(&addr, &mut cache).unwrap();
    assert_eq!(stats.imported, 0);
    assert_eq!(stats.skipped, 2, "both broken lines counted, neither fatal");
    assert_eq!(cache.len(), 0);
}

#[test]
fn sync_errors_when_the_peer_dies_mid_stream() {
    let addr = fake_sync_peer(CACHE_VERSION, vec!["junk".into()], false);
    let mut cache = ResultCache::in_memory();
    let err = sync_from_peer(&addr, &mut cache).unwrap_err();
    assert!(err.contains("sync_end"), "unexpected error: {err}");
}

#[test]
fn failover_reroutes_to_next_ranked_member_bit_identically() {
    let (live, daemon) = start_server(None);
    let dead = dead_addr();
    let cluster = Cluster::new(vec![live.clone(), dead.clone()]).unwrap();
    let dead_idx = cluster.members().iter().position(|m| m == &dead).unwrap();
    let live_idx = 1 - dead_idx;

    // find a job the *dead* member owns, so the request must fail over
    let spec = (1..=64u64)
        .map(|seed| search_spec("gemm:16x16x16", 60, seed))
        .find(|s| {
            cluster.owner(&job_signature(&resolve_spec(s).unwrap())) == dead_idx
        })
        .expect("some seed in 1..=64 hashes to the dead member");
    let sig = job_signature(&resolve_spec(&spec).unwrap());

    let mut cc = ClusterClient::new(cluster, 0xFA11);
    let request = Request::Search { id: None, spec: spec.clone(), progress: false };
    let (answered_by, doc) = cc.request(&sig, &request).unwrap();
    assert_eq!(answered_by, live_idx, "the live member must answer");
    assert_eq!(doc.str("type"), Some("result"), "{}", doc.to_line());
    assert!(!cc.peer_up(dead_idx), "the dead owner is marked down");
    assert!(cc.peer_up(live_idx));

    // the re-routed answer is still byte-identical to a direct run
    let mapping = mapping_from_json(doc.get("mapping").unwrap()).unwrap();
    let job = resolve_spec(&spec).unwrap();
    let direct = {
        use union::network::{NetworkOrchestrator, OrchestratorConfig, WorkloadGraph};
        let graph = WorkloadGraph::from_workloads("direct", vec![job.workload.clone()]);
        let orch = NetworkOrchestrator::with_config(
            &job.arch,
            job.cost.model(),
            &job.constraints,
            OrchestratorConfig {
                objective: job.objective,
                samples: job.samples,
                seed: job.seed,
                threads: Some(1),
            },
        );
        orch.run(&graph).unwrap()
    };
    let direct_best = &direct.layers[0].result;
    assert_eq!(mapping, direct_best.mapping, "failover changed the mapping");
    assert_eq!(
        doc.num("score").unwrap().to_bits(),
        direct_best.score.to_bits(),
        "failover changed the score bits"
    );

    shutdown(&live, daemon);
}

#[test]
fn restarted_member_rewarms_from_a_neighbor_snapshot() {
    // peer A accumulates results; a "restarted" peer B starts with an
    // empty cache file, imports A's snapshot, and then serves the same
    // jobs as warm hits without searching
    let (a_addr, a_daemon) = start_server(None);
    let specs = [search_spec("gemm:32x16x8", 60, 3), search_spec("gemm:8x8x8", 60, 5)];
    let mut scores = Vec::new();
    for spec in &specs {
        let doc = client_request(
            &a_addr,
            &Request::Search { id: None, spec: spec.clone(), progress: false },
        )
        .unwrap();
        scores.push(doc.num("score").unwrap().to_bits());
    }

    let b_cache = tmp_path("rewarm");
    let _ = std::fs::remove_file(&b_cache);
    {
        let mut cache = ResultCache::open(&b_cache).unwrap();
        let stats = sync_from_peer(&a_addr, &mut cache).unwrap();
        assert_eq!(stats.imported, 2);
    } // drop flushes the snapshot to disk

    let (b_addr, b_daemon) = start_server(Some(b_cache.clone()));
    for (spec, bits) in specs.iter().zip(&scores) {
        let doc = client_request(
            &b_addr,
            &Request::Search { id: None, spec: spec.clone(), progress: false },
        )
        .unwrap();
        assert_eq!(doc.bool_field("cached"), Some(true), "{}", doc.to_line());
        assert_eq!(doc.num("score").unwrap().to_bits(), *bits);
    }
    let b_stats = shutdown(&b_addr, b_daemon);
    assert_eq!(b_stats.searched, 0, "a synced member must not re-search");
    assert_eq!(b_stats.cache_hits, 2);

    shutdown(&a_addr, a_daemon);
    let _ = std::fs::remove_file(&b_cache);
}

#[test]
fn router_forwards_to_owners_and_reports_status() {
    let (a_addr, a_daemon) = start_server(None);
    let (b_addr, b_daemon) = start_server(None);
    let peers = vec![a_addr.clone(), b_addr.clone()];
    let cluster = Cluster::new(peers.clone()).unwrap();

    let router = Router::bind(RouterConfig {
        port: 0,
        peers,
        ..RouterConfig::default()
    })
    .unwrap();
    let router_addr = router.local_addr().unwrap().to_string();
    let router_thread = thread::spawn(move || router.run());

    // a dumb client speaks plain search to the router; the owner answers
    let spec = search_spec("gemm:16x24x16", 60, 2);
    let doc = client_request(
        &router_addr,
        &Request::Search { id: None, spec: spec.clone(), progress: false },
    )
    .unwrap();
    assert_eq!(doc.str("type"), Some("result"), "{}", doc.to_line());

    // the owner now holds the result: asking it directly is a cache hit
    // with the same bits (the router forwarded, not re-searched)
    let sig = job_signature(&resolve_spec(&spec).unwrap());
    let owner = &cluster.members()[cluster.owner(&sig)];
    let again = client_request(
        owner,
        &Request::Search { id: None, spec: spec.clone(), progress: false },
    )
    .unwrap();
    assert_eq!(again.bool_field("cached"), Some(true), "{}", again.to_line());
    assert_eq!(
        again.num("score").unwrap().to_bits(),
        doc.num("score").unwrap().to_bits()
    );

    // router status is its own shape: per-peer health plus counters
    let status = client_request(&router_addr, &Request::Status { id: None }).unwrap();
    assert_eq!(status.bool_field("router"), Some(true));
    assert_eq!(status.arr("peers").unwrap().len(), 2);
    assert!(status.num("forwarded").unwrap() >= 1.0);
    assert_eq!(status.num("failovers").unwrap(), 0.0);

    // sync must not be proxied: snapshots come from a specific peer
    let refused = client_request(&router_addr, &Request::Sync { id: None }).unwrap();
    assert_eq!(refused.str("type"), Some("error"), "{}", refused.to_line());

    // shutdown stops the router only; both peers keep serving
    let ack = client_request(&router_addr, &Request::Shutdown { id: None }).unwrap();
    assert_eq!(ack.bool_field("router"), Some(true));
    router_thread.join().unwrap().unwrap();
    assert!(client_request(&a_addr, &Request::Status { id: None }).is_ok());
    assert!(client_request(&b_addr, &Request::Status { id: None }).is_ok());

    shutdown(&a_addr, a_daemon);
    shutdown(&b_addr, b_daemon);
}
