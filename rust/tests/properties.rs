//! Property-based tests over the Union abstractions, via the in-tree
//! quickcheck substrate (`union::util::quickcheck`). Each property runs
//! hundreds of randomized cases with deterministic replay seeds.

use union::arch::presets;
use union::cost::{
    AnalyticalModel, CostModel, EnergyTable, MaestroModel, ReuseModel, SparseModel, TileAnalysis,
    TileScratch,
};
use union::mapspace::{constraints_from_str, constraints_to_str, Constraints, MapSpace};
use union::problem::{conv2d, gemm, Problem};
use union::transfer::{project_mapping, ProblemFeatures, TransferIndex};
use union::util::divisors::{divisors, tilings};
use union::util::quickcheck::{Gen, QuickCheck};
use union::util::rng::Rng;

/// Draw a random "nice" size: product of small factors, 1..=96.
fn nice_size(g: &mut Gen) -> u64 {
    let factors = [2u64, 2, 2, 3, 3, 5, 7];
    let mut n = 1u64;
    for _ in 0..g.range(0, 5) {
        n *= *g.choose(&factors);
        if n > 96 {
            break;
        }
    }
    n.min(96).max(1)
}

#[test]
fn prop_sampled_mappings_satisfy_all_legality_rules() {
    QuickCheck::new().cases(120).seed(0xA11CE).check("sampled-legal", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::fig5_toy();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        match space.sample_legal(&mut rng, 500) {
            Some(m) => m
                .check(&p, &arch)
                .map_err(|e| format!("illegal sampled mapping: {e} for {p}")),
            None => Ok(()), // tiny/degenerate spaces may have no admit
        }
    });
}

#[test]
fn prop_packed_encode_decode_roundtrips() {
    // the packed mapping code is lossless: encode → decode reproduces
    // every legal mapping exactly, and re-encoding reproduces the
    // fingerprint (so memo keys are stable across trips)
    QuickCheck::new().cases(150).seed(0xFACADE).check("packed-roundtrip", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::fig5_toy();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let packed = space.encode(&m);
        let decoded = space.decode(packed.as_ref());
        if decoded != m {
            return Err(format!("round trip changed the mapping:\n{m}\nvs\n{decoded}"));
        }
        let repacked = space.encode(&decoded);
        if !packed.as_ref().code_eq(&repacked.as_ref()) {
            return Err("re-encoding produced a different code".into());
        }
        if packed.as_ref().fingerprint() != repacked.as_ref().fingerprint() {
            return Err("fingerprint not stable across a round trip".into());
        }
        if packed.as_ref().pes_used() != m.pes_used() {
            return Err("packed pes_used disagrees with the mapping".into());
        }
        Ok(())
    });
}

#[test]
fn prop_packed_path_scores_bit_identical_to_mapping_path() {
    // the engine's allocation-free lean path must produce BIT-identical
    // scores to the legacy full-estimate path, for both cost models,
    // with and without the footprint memo in play
    QuickCheck::new().cases(100).seed(0x1EAF).check("lean-bit-identical", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::edge();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        // the packed round trip feeds the lean path exactly what the
        // engine's decode step would
        let decoded = space.decode(space.encode(&m).as_ref());
        let analytical = AnalyticalModel::new(EnergyTable::default_8bit());
        let maestro = MaestroModel::new(EnergyTable::default_8bit());
        let models: [(&str, &dyn CostModel); 2] =
            [("analytical", &analytical), ("maestro", &maestro)];
        let mut scratch = TileScratch::new();
        let mut memo = union::cost::FootprintMemo::new();
        for lvl in &m.levels {
            memo.get_or_compute(&p, &lvl.temporal_tile);
        }
        for (name, model) in models {
            let full = model
                .evaluate_prechecked(&p, &arch, &m)
                .map_err(|e| format!("{name}: full path failed: {e}"))?;
            for fpm in [None, Some(&memo)] {
                let lean = model
                    .evaluate_lean(&p, &arch, &decoded, &mut scratch, fpm)
                    .map_err(|e| format!("{name}: lean path failed: {e}"))?;
                if lean.cycles.to_bits() != full.cycles.to_bits() {
                    return Err(format!(
                        "{name}: cycles differ: lean {} vs full {}",
                        lean.cycles, full.cycles
                    ));
                }
                if lean.energy_pj.to_bits() != full.energy_pj.to_bits() {
                    return Err(format!(
                        "{name}: energy differs: lean {} vs full {}",
                        lean.energy_pj, full.energy_pj
                    ));
                }
                if lean.edp().to_bits() != full.edp().to_bits() {
                    return Err(format!("{name}: EDP differs"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_at_density_one_is_bit_identical_to_base() {
    // a SparseModel at density 1.0 with zero metadata overhead IS its
    // base model: every scalar of every legal mapping must match
    // bit-for-bit, on both the full and the lean path (the density-1.0
    // anchor of the sparsity case study depends on this)
    QuickCheck::new().cases(100).seed(0xDE15E).check("sparse-identity", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::edge();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let base = AnalyticalModel::new(EnergyTable::default_8bit());
        let sparse =
            SparseModel::uniform(AnalyticalModel::new(EnergyTable::default_8bit()), 1.0, 0.0);
        let be = base.evaluate_prechecked(&p, &arch, &m).map_err(|e| e.to_string())?;
        let se = sparse.evaluate_prechecked(&p, &arch, &m).map_err(|e| e.to_string())?;
        if se.macs != be.macs {
            return Err(format!("macs differ: sparse {} vs base {}", se.macs, be.macs));
        }
        if se.cycles.to_bits() != be.cycles.to_bits() {
            return Err(format!("cycles differ: sparse {} vs base {}", se.cycles, be.cycles));
        }
        if se.energy_pj.to_bits() != be.energy_pj.to_bits() {
            return Err(format!(
                "energy differs: sparse {} vs base {}",
                se.energy_pj, be.energy_pj
            ));
        }
        for (sl, bl) in se.levels.iter().zip(&be.levels) {
            if sl.reads.to_bits() != bl.reads.to_bits()
                || sl.writes.to_bits() != bl.writes.to_bits()
                || sl.energy_pj.to_bits() != bl.energy_pj.to_bits()
            {
                return Err(format!("{}: level stats differ at density 1.0", sl.level_name));
            }
        }
        let mut scratch = TileScratch::new();
        let lean = sparse
            .evaluate_lean(&p, &arch, &m, &mut scratch, None)
            .map_err(|e| e.to_string())?;
        if lean.cycles.to_bits() != be.cycles.to_bits()
            || lean.energy_pj.to_bits() != be.energy_pj.to_bits()
            || lean.macs != be.macs
        {
            return Err("lean sparse path differs from the base at density 1.0".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_lean_path_bit_identical_to_full() {
    // the sparse wrapper inherits the engine's lean/full bit-identity
    // contract at ANY density, with and without the footprint memo —
    // the engine debug-asserts exactly this when a sparse incumbent is
    // materialized
    QuickCheck::new().cases(100).seed(0x5BA25E).check("sparse-lean-bit-identical", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::edge();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let decoded = space.decode(space.encode(&m).as_ref());
        let density = g.range(0, 100) as f64 / 100.0;
        let meta = g.range(0, 50) as f64 / 100.0;
        let model =
            SparseModel::uniform(AnalyticalModel::new(EnergyTable::default_8bit()), density, meta);
        let full = model
            .evaluate_prechecked(&p, &arch, &m)
            .map_err(|e| format!("full path failed: {e}"))?;
        let mut scratch = TileScratch::new();
        let mut memo = union::cost::FootprintMemo::new();
        for lvl in &m.levels {
            memo.get_or_compute(&p, &lvl.temporal_tile);
        }
        for fpm in [None, Some(&memo)] {
            let lean = model
                .evaluate_lean(&p, &arch, &decoded, &mut scratch, fpm)
                .map_err(|e| format!("lean path failed: {e}"))?;
            if lean.cycles.to_bits() != full.cycles.to_bits() {
                return Err(format!(
                    "d={density} meta={meta}: cycles differ: lean {} vs full {}",
                    lean.cycles, full.cycles
                ));
            }
            if lean.energy_pj.to_bits() != full.energy_pj.to_bits() {
                return Err(format!(
                    "d={density} meta={meta}: energy differs: lean {} vs full {}",
                    lean.energy_pj, full.energy_pj
                ));
            }
            if lean.macs != full.macs {
                return Err(format!("d={density} meta={meta}: macs differ"));
            }
            if lean.edp().to_bits() != full.edp().to_bits() {
                return Err(format!("d={density} meta={meta}: EDP differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_lower_bounds_never_exceed_the_estimate() {
    // pruning soundness for the sparse kind: both bounds must stay
    // under the true sparse cost for every legal mapping and density
    QuickCheck::new().cases(100).seed(0xB0B5D).check("sparse-bounds", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::edge();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let density = g.range(0, 100) as f64 / 100.0;
        let model =
            SparseModel::uniform(AnalyticalModel::new(EnergyTable::default_8bit()), density, 0.05);
        let e = model.evaluate_prechecked(&p, &arch, &m).map_err(|x| x.to_string())?;
        let Some(b) = model.lower_bound(&p, &arch, &m) else {
            return Err("sparse wrapper dropped the base lower bound".into());
        };
        if b.cycles > e.cycles + 1e-9 {
            return Err(format!("d={density}: bound cycles {} > estimate {}", b.cycles, e.cycles));
        }
        if b.energy_pj > e.energy_pj + 1e-9 {
            return Err(format!("d={density}: bound energy {} > {}", b.energy_pj, e.energy_pj));
        }
        let Some(ab) = model.arch_lower_bound(&p, &arch) else {
            return Err("sparse wrapper dropped the arch lower bound".into());
        };
        if ab.cycles > e.cycles + 1e-9 || ab.energy_pj > e.energy_pj + 1e-9 {
            return Err(format!("d={density}: arch bound exceeds the estimate"));
        }
        Ok(())
    });
}

#[test]
fn prop_trips_times_parallelism_cover_every_dim() {
    QuickCheck::new().cases(100).seed(0xB0B).check("coverage-product", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::fig5_toy();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        for d in 0..p.dims.len() {
            // Π over levels of (trips·parallelism) telescopes to
            // D / ST_innermost; the innermost spatial tile iterates
            // implicitly inside the PE (its L1-resident chunk)
            let product: u64 = (0..arch.depth())
                .map(|i| m.trips(&p, i, d) * m.parallelism(i, d))
                .product();
            let inner_st = m.levels.last().unwrap().spatial_tile[d];
            if product * inner_st != p.dims[d].size {
                return Err(format!(
                    "dim {d}: covered {product} x inner {inner_st} != {}",
                    p.dims[d].size
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_order_agnostic_reuse_is_lower_bound() {
    // MAESTRO-style optimism can never move MORE data than the
    // order-aware count — for every data space at every level
    QuickCheck::new().cases(80).seed(0xCAFE).check("reuse-bound", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::fig5_toy();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let mut ta = TileAnalysis::new(&p, &arch, &m);
        let aware = ta.movement(ReuseModel::OrderAware);
        let agnostic = ta.movement(ReuseModel::OrderAgnostic);
        for (ds, (a, b)) in aware.detail.iter().zip(&agnostic.detail).enumerate() {
            for (lvl, (la, lb)) in a.iter().zip(b).enumerate() {
                if lb.fills > la.fills + 1e-9 {
                    return Err(format!(
                        "ds {ds} level {lvl}: agnostic {} > aware {}",
                        lb.fills, la.fills
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fills_at_least_footprint() {
    // every tile must be loaded at least once: fills >= footprint
    QuickCheck::new().cases(80).seed(0xF111).check("fills-lb", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::fig5_toy();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let mut ta = TileAnalysis::new(&p, &arch, &m);
        let mv = ta.movement(ReuseModel::OrderAware);
        for per_ds in &mv.detail {
            for lvl in per_ds {
                if lvl.fills + 1e-9 < lvl.footprint as f64 {
                    return Err(format!(
                        "fills {} < footprint {}",
                        lvl.fills, lvl.footprint
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cost_positive_and_compute_bounded() {
    // any legal mapping: cycles >= MACs / PEs, energy > MAC floor
    QuickCheck::new().cases(80).seed(0xD00D).check("cost-bounds", |g| {
        let p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::fig5_toy();
        let cons = Constraints::default();
        let space = MapSpace::new(&p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(m) = space.sample_legal(&mut rng, 500) else { return Ok(()) };
        let model = AnalyticalModel::new(EnergyTable::default_8bit());
        let e = model.evaluate(&p, &arch, &m).map_err(|x| x.to_string())?;
        let compute_lb = p.total_macs() as f64 / arch.num_pes() as f64;
        if e.cycles + 1e-9 < compute_lb {
            return Err(format!("cycles {} below compute bound {compute_lb}", e.cycles));
        }
        let mac_floor = p.total_macs() as f64 * 0.2;
        if e.energy_pj < mac_floor {
            return Err(format!("energy {} below MAC floor {mac_floor}", e.energy_pj));
        }
        Ok(())
    });
}

#[test]
fn prop_conv_footprint_matches_brute_force() {
    // the projection-based tile footprint equals a brute-force count of
    // distinct input elements touched by a tile
    QuickCheck::new().cases(60).seed(0x5EED5).check("conv-footprint", |g| {
        let x = g.range(1, 6) as u64;
        let r = g.range(1, 4) as u64;
        let stride = g.range(1, 3) as u64;
        let p = conv2d(1, 1, 1, x, x, r, r, stride);
        let input = p
            .data_spaces
            .iter()
            .find(|d| d.name == "Input")
            .unwrap();
        // tile spanning (tx, tr) in the X and R dims
        let tx = g.range(1, x as usize) as u64;
        let tr = g.range(1, r as usize) as u64;
        let mut tile = vec![1u64; p.dims.len()];
        tile[p.dim_index("X").unwrap()] = tx;
        tile[p.dim_index("R").unwrap()] = tr;
        let formula = input.tile_footprint(&tile);
        // the formula models the bounding-box extent (contiguous
        // allocation, Timeloop-style); brute-force both the extent and
        // the distinct-element count
        let mut seen = std::collections::HashSet::new();
        let mut max_idx = 0u64;
        for xi in 0..tx {
            for ri in 0..tr {
                let idx = xi * stride + ri;
                seen.insert(idx);
                max_idx = max_idx.max(idx);
            }
        }
        let extent = max_idx + 1;
        if formula != extent {
            return Err(format!(
                "x={x} r={r} s={stride} tile=({tx},{tr}): formula {formula} != extent {extent}"
            ));
        }
        if formula < seen.len() as u64 {
            return Err(format!(
                "footprint {formula} below distinct-element count {}",
                seen.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_tilings_partition_divisors() {
    QuickCheck::new().cases(100).seed(0x714).check("tilings", |g| {
        let n = nice_size(g);
        let k = g.range(1, 4);
        for t in tilings(n, k) {
            if t.iter().product::<u64>() != n {
                return Err(format!("tiling {t:?} of {n} broken"));
            }
            for v in &t {
                if !divisors(n).contains(v) {
                    return Err(format!("{v} not a divisor of {n}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_config_roundtrip() {
    // Display(parse(x)) re-parses to the same document
    QuickCheck::new().cases(60).seed(0xC0FF).check("config-roundtrip", |g| {
        let n = g.range(1, 6);
        let mut src = String::from("name: t\n");
        for i in 0..n {
            src.push_str(&format!("k{i}: {}\n", g.range(0, 1000)));
        }
        src.push_str("list:\n");
        for i in 0..g.range(1, 4) {
            src.push_str(&format!("  - item: {i}\n    v: {}\n", g.range(0, 9)));
        }
        let doc = union::config::parse(&src).map_err(|e| e.to_string())?;
        let doc2 = union::config::parse(&doc.to_string()).map_err(|e| e.to_string())?;
        if doc != doc2 {
            return Err(format!("roundtrip mismatch:\n{doc}\nvs\n{doc2}"));
        }
        Ok(())
    });
}

/// Draw a random [`Constraints`] covering every field, including
/// `max_parallel_dims_per_level`. Utilization bounds come from a 1/64
/// grid (exact in binary and in decimal rendering), dim names from the
/// CONV2D/GEMM vocabulary.
fn random_constraints(g: &mut Gen) -> Constraints {
    let names = ["N", "K", "C", "X", "Y", "R", "S", "M"];
    let mut c = Constraints::default();
    if g.range(0, 1) == 1 {
        let n = g.range(1, 4);
        c.parallel_dims = Some(g.vec(n, |g| g.choose(&names).to_string()));
    }
    let a = g.range(0, 64) as f64 / 64.0;
    let b = g.range(0, 64) as f64 / 64.0;
    c.min_utilization = a.min(b);
    c.max_utilization = a.max(b);
    for _ in 0..g.range(0, 2) {
        let level = g.range(0, 3);
        let len = g.range(1, 7);
        let order = g.vec(len, |g| g.choose(&names).to_string());
        c.fixed_orders.push((level, order));
    }
    if g.range(0, 1) == 1 {
        let len = g.range(1, 6);
        c.allowed_tile_sizes = Some(g.vec(len, |g| 1u64 << g.range(0, 7)));
    }
    if g.range(0, 1) == 1 {
        c.max_parallel_dims_per_level = Some(g.range(1, 4));
    }
    c
}

/// Render the canonical signature `job_signature` (service/broker.rs)
/// produces for a dense analytical EDP job — the string form the
/// transfer index consumes (the exact-shape round trip against the real
/// broker is pinned by its unit tests).
fn transfer_sig(p: &Problem, samples: usize, seed: u64) -> String {
    format!(
        "union-job-v1|{}|arch=edge#00deadbeef00cafe|model=analytical|cons=|obj=edp|samples={samples}|seed={seed}",
        p.signature()
    )
    .replace('\n', ";")
}

#[test]
fn prop_transfer_distance_is_a_symmetric_premetric() {
    // d(a,a) == 0 and d(a,b) == d(b,a) bit-for-bit, for every pair of
    // same-family signatures; incompatible pairs are +inf both ways
    QuickCheck::new().cases(150).seed(0x7F_A57).check("transfer-distance", |g| {
        let pa = gemm(nice_size(g), nice_size(g), nice_size(g));
        let pb = gemm(nice_size(g), nice_size(g), nice_size(g));
        let sa = transfer_sig(&pa, 400, 1);
        let sb = transfer_sig(&pb, 500, 2);
        let fa = ProblemFeatures::from_signature(&sa)
            .ok_or_else(|| format!("unparseable signature: {sa}"))?;
        let fb = ProblemFeatures::from_signature(&sb)
            .ok_or_else(|| format!("unparseable signature: {sb}"))?;
        if fa.distance(&fa) != 0.0 {
            return Err(format!("d(a,a) = {} != 0", fa.distance(&fa)));
        }
        let (ab, ba) = (fa.distance(&fb), fb.distance(&fa));
        if ab.to_bits() != ba.to_bits() {
            return Err(format!("asymmetric: d(a,b)={ab} vs d(b,a)={ba}"));
        }
        if !ab.is_finite() {
            return Err(format!("same-family pair must be compatible: {ab}"));
        }
        // a CONV2D job is a different operator family: infinite both ways
        let pc = conv2d(1, 4, 4, 8, 8, 3, 3, 1);
        let fc = ProblemFeatures::from_signature(&transfer_sig(&pc, 400, 1))
            .ok_or("unparseable conv signature")?;
        if fa.distance(&fc).is_finite() || fc.distance(&fa).is_finite() {
            return Err("cross-operator distance must be +inf".into());
        }
        Ok(())
    });
}

#[test]
fn prop_projected_seeds_always_pass_legality() {
    // whatever the donor/query size pair, a projected mapping is either
    // rejected (None) or passes the full legality check of the QUERY
    // space — seeds never bypass admits/check
    QuickCheck::new().cases(120).seed(0x5EED_CA57).check("transfer-project-legal", |g| {
        let donor_p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let query_p = gemm(nice_size(g), nice_size(g), nice_size(g));
        let arch = presets::edge();
        let cons = Constraints::default();
        let donor_space = MapSpace::new(&donor_p, &arch, &cons);
        let query_space = MapSpace::new(&query_p, &arch, &cons);
        let mut rng = Rng::new(g.rng().next_u64());
        let Some(donor_m) = donor_space.sample_legal(&mut rng, 500) else { return Ok(()) };
        match project_mapping(&query_space, &donor_m) {
            None => Ok(()), // rejection is always a legal answer
            Some(m) => {
                if !query_space.admits(&m) {
                    return Err(format!(
                        "projected mapping not admitted: donor {donor_p} query {query_p}"
                    ));
                }
                m.check(&query_p, &arch)
                    .map_err(|e| format!("projected mapping illegal: {e} for {query_p}"))
            }
        }
    });
}

#[test]
fn prop_transfer_lookup_is_insertion_order_invariant() {
    // the index's neighbor ranking is a total order over
    // (distance bits, signature): inserting the same entries forward or
    // reversed must return identical neighbor lists for any query
    QuickCheck::new().cases(80).seed(0x0DE2).check("transfer-lookup-deterministic", |g| {
        let arch = presets::edge();
        let cons = Constraints::default();
        let n = g.range(2, 8);
        let mut entries = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for i in 0..n {
            let p = gemm(nice_size(g), nice_size(g), nice_size(g));
            let sig = transfer_sig(&p, 400, i as u64);
            if !seen.insert(sig.clone()) {
                continue; // same shape drawn twice: one canonical entry
            }
            let space = MapSpace::new(&p, &arch, &cons);
            let mut rng = Rng::new(g.rng().next_u64());
            let Some(m) = space.sample_legal(&mut rng, 500) else { continue };
            let score = 1.0 + g.range(0, 1000) as f64;
            entries.push((sig, m, score));
        }
        let mut fwd = TransferIndex::new();
        for (sig, m, s) in &entries {
            fwd.insert(sig, m, *s);
        }
        let mut rev = TransferIndex::new();
        for (sig, m, s) in entries.iter().rev() {
            rev.insert(sig, m, *s);
        }
        let query = transfer_sig(&gemm(nice_size(g), nice_size(g), nice_size(g)), 400, 99);
        for k in 1..=entries.len().max(1) {
            let a = fwd.lookup(&query, k);
            let b = rev.lookup(&query, k);
            if a.len() != b.len() {
                return Err(format!("k={k}: {} vs {} neighbors", a.len(), b.len()));
            }
            for (x, y) in a.iter().zip(&b) {
                if x.sig != y.sig
                    || x.distance.to_bits() != y.distance.to_bits()
                    || x.score.to_bits() != y.score.to_bits()
                    || x.mapping != y.mapping
                {
                    return Err(format!(
                        "k={k}: neighbor lists diverge at {} vs {}",
                        x.sig, y.sig
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_constraints_roundtrip_parse_render_parse() {
    // parse(render(c)) == c for every field combination, and render is
    // a fixpoint (render(parse(render(c))) == render(c))
    QuickCheck::new().cases(200).seed(0xC0_75).check("constraints-roundtrip", |g| {
        let c = random_constraints(g);
        let text = constraints_to_str(&c);
        let parsed = constraints_from_str(&text)
            .map_err(|e| format!("rendered file unparseable: {e}\n---\n{text}"))?;
        if parsed != c {
            return Err(format!(
                "round trip changed constraints:\n{c:?}\nvs\n{parsed:?}\n---\n{text}"
            ));
        }
        let text2 = constraints_to_str(&parsed);
        if text2 != text {
            return Err(format!("render not a fixpoint:\n---\n{text}\n---\n{text2}"));
        }
        Ok(())
    });
}
