//! Network-level orchestration: cross-layer dedup correctness on the
//! full ResNet-50 graph, result re-expansion, and report structure.

use union::arch::presets;
use union::cost::{AnalyticalModel, EnergyTable};
use union::frontend::{self, WorkloadKind};
use union::mapspace::Constraints;
use union::network::{NetworkOrchestrator, OrchestratorConfig};

/// ResNet-50's distinct search-job count: 23 distinct CONV2D shapes
/// across the 53 convolutions, plus the classifier GEMM.
const RESNET50_DISTINCT_JOBS: usize = 24;

fn fast_config(samples: usize) -> OrchestratorConfig {
    OrchestratorConfig { samples, seed: 7, ..OrchestratorConfig::default() }
}

#[test]
fn resnet50_graph_has_53_convs_plus_fc() {
    let g = frontend::resnet50_full(1);
    assert_eq!(g.total_layers(), 54);
    let convs: u64 = g
        .nodes()
        .iter()
        .filter(|n| matches!(n.workload.kind, WorkloadKind::Conv2d { .. }))
        .map(|n| n.repeat)
        .sum();
    assert_eq!(convs, 53);
}

#[test]
fn orchestrator_evaluates_only_distinct_shapes_on_resnet50() {
    let g = frontend::resnet50_full(1);
    let arch = presets::edge();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let orch = NetworkOrchestrator::with_config(&arch, &model, &cons, fast_config(150));
    let r = orch.run(&g).expect("ResNet-50 maps end-to-end on edge");

    // THE dedup claim: distinct jobs equal the distinct-shape count,
    // not the raw layer count
    assert_eq!(r.stats.distinct_jobs, RESNET50_DISTINCT_JOBS);
    assert_eq!(r.stats.layers, 54);
    assert!(r.stats.distinct_jobs < r.stats.layers as usize);
    let expected_rate = (54.0 - RESNET50_DISTINCT_JOBS as f64) / 54.0;
    assert!((r.stats.dedup_hit_rate - expected_rate).abs() < 1e-12);

    // every node got a result; dedup hits share their job's result exactly
    assert_eq!(r.layers.len(), g.len());
    assert!(r.layers.iter().any(|l| l.dedup_hit));
    for l in &r.layers {
        let first = r
            .layers
            .iter()
            .find(|o| o.job == l.job)
            .expect("job has a first layer");
        assert!(!first.dedup_hit, "first layer of a job must be the searched one");
        assert_eq!(l.result.score, first.result.score, "{}", l.name);
        assert_eq!(l.result.mapping, first.result.mapping, "{}", l.name);
    }

    // rollups: totals accumulate repeat-weighted per-layer costs
    let cycles: f64 = r
        .layers
        .iter()
        .map(|l| l.result.cost.cycles * l.repeat as f64)
        .sum();
    assert!((r.total_cycles - cycles).abs() <= 1e-6 * cycles.abs());
    assert!(r.total_energy_j > 0.0 && r.total_latency_s > 0.0);
    assert!((r.edp() - r.total_energy_j * r.total_latency_s).abs() <= f64::EPSILON * r.edp());
}

#[test]
fn per_layer_table_groups_stages_and_rolls_up() {
    let g = frontend::resnet50_full(1);
    let arch = presets::edge();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let orch = NetworkOrchestrator::with_config(&arch, &model, &cons, fast_config(100));
    let r = orch.run(&g).expect("network maps");
    let t = r.per_layer_table();
    assert_eq!(t.rows.len(), r.layers.len());
    assert!(t.rollup.is_some(), "network table must carry a rollup row");
    assert_eq!(t.group_col, Some(0));
    let rendered = t.render();
    assert!(rendered.contains("conv1"));
    assert!(rendered.contains("fc1000"));
    assert!(rendered.contains("reused"));
    // CSV includes the rollup as the last record
    let csv = t.to_csv();
    assert_eq!(csv.lines().count(), 1 + t.rows.len() + 1);
}

#[test]
fn duplicate_nodes_in_a_custom_graph_dedup_to_one_job() {
    use union::frontend::Workload;
    use union::network::WorkloadGraph;
    let mut g = WorkloadGraph::new("dup");
    // same shape under three different layer names + one odd one out
    g.add(Workload::gemm("fc_a", 64, 64, 64));
    g.add(Workload::gemm("fc_b", 64, 64, 64));
    g.add_repeated(Workload::gemm("fc_c", 64, 64, 64), 2);
    g.add(Workload::gemm("fc_d", 32, 32, 32));
    let arch = presets::edge();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let orch = NetworkOrchestrator::with_config(&arch, &model, &cons, fast_config(200));
    let r = orch.run(&g).expect("maps");
    assert_eq!(r.stats.distinct_jobs, 2);
    assert_eq!(r.stats.layers, 5);
    assert_eq!(r.layers[0].job, r.layers[1].job);
    assert_eq!(r.layers[0].job, r.layers[2].job);
    assert!(!r.layers[0].dedup_hit);
    assert!(r.layers[1].dedup_hit && r.layers[2].dedup_hit);
    assert!(!r.layers[3].dedup_hit);
    assert_ne!(r.layers[3].job, r.layers[0].job);
}

#[test]
fn empty_graph_is_rejected() {
    use union::network::WorkloadGraph;
    let g = WorkloadGraph::new("empty");
    let arch = presets::edge();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let orch = NetworkOrchestrator::new(&arch, &model, &cons);
    assert!(orch.run(&g).is_err());
}
