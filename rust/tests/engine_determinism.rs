//! Engine determinism contract: same seed + same workload ⇒ identical
//! best mapping, regardless of worker thread count, for all five
//! mappers — and the engine's accelerations (memoization, lower-bound
//! pruning) never change the winner.

use union::arch::presets;
use union::cost::{AnalyticalModel, CostModel, EnergyTable};
use union::engine::{Engine, EngineConfig};
use union::mappers::{
    DecoupledMapper, ExhaustiveMapper, GeneticMapper, HeuristicMapper, Mapper, Objective,
    RandomMapper, SearchResult,
};
use union::mapspace::{Constraints, MapSpace};
use union::problem::gemm;

fn mappers() -> Vec<(&'static str, Box<dyn Mapper>)> {
    vec![
        ("random", Box::new(RandomMapper::new(800, 11))),
        ("exhaustive", Box::new(ExhaustiveMapper::new(3_000))),
        ("genetic", Box::new(GeneticMapper::new(30, 4, 11))),
        ("heuristic", Box::new(HeuristicMapper::new(200, 30, 11))),
        ("decoupled", Box::new(DecoupledMapper::new(100, 30, 11))),
    ]
}

fn search_configured(
    mapper: &dyn Mapper,
    space: &MapSpace,
    model: &dyn CostModel,
    config: EngineConfig,
) -> Option<SearchResult> {
    let mut engine = Engine::with_config(space, model, Objective::Edp, config);
    engine.run(mapper.source().as_mut())
}

#[test]
fn identical_best_mapping_at_one_and_many_threads() {
    let p = gemm(32, 32, 32);
    let a = presets::edge();
    let c = Constraints::default();
    let space = MapSpace::new(&p, &a, &c);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for (name, mapper) in mappers() {
        let cfg_1 = EngineConfig { threads: Some(1), ..EngineConfig::default() };
        let cfg_n = EngineConfig { threads: Some(8), ..EngineConfig::default() };
        let r1 = search_configured(mapper.as_ref(), &space, &model, cfg_1)
            .unwrap_or_else(|| panic!("{name}: no result at 1 thread"));
        let rn = search_configured(mapper.as_ref(), &space, &model, cfg_n)
            .unwrap_or_else(|| panic!("{name}: no result at 8 threads"));
        assert_eq!(r1.mapping, rn.mapping, "{name}: best mapping depends on thread count");
        assert_eq!(r1.score, rn.score, "{name}: best score depends on thread count");
        assert_eq!(r1.evaluated, rn.evaluated, "{name}: scored count depends on threads");
    }
}

#[test]
fn identical_best_mapping_on_repeat_runs() {
    let p = gemm(32, 32, 32);
    let a = presets::edge();
    let c = Constraints::default();
    let space = MapSpace::new(&p, &a, &c);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for (name, mapper) in mappers() {
        let r1 = mapper.search(&space, &model).unwrap_or_else(|| panic!("{name}: no result"));
        let r2 = mapper.search(&space, &model).unwrap_or_else(|| panic!("{name}: no result"));
        assert_eq!(r1.mapping, r2.mapping, "{name}: not reproducible across runs");
        assert_eq!(r1.score, r2.score, "{name}: score not reproducible");
    }
}

#[test]
fn pruning_and_memoization_never_change_the_winner() {
    // feedback-free (or incumbent-only) sources must produce the exact
    // same winner with the accelerations on and off; the genetic source
    // is excluded because pruning legitimately reshapes its parent pool
    let p = gemm(32, 32, 32);
    let a = presets::edge();
    let c = Constraints::default();
    let space = MapSpace::new(&p, &a, &c);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let subset: Vec<(&str, Box<dyn Mapper>)> = vec![
        ("random", Box::new(RandomMapper::new(800, 11))),
        ("exhaustive", Box::new(ExhaustiveMapper::new(3_000))),
        ("heuristic", Box::new(HeuristicMapper::new(200, 30, 11))),
        ("decoupled", Box::new(DecoupledMapper::new(100, 30, 11))),
    ];
    for (name, mapper) in subset {
        let plain = EngineConfig { prune: false, memoize: false, ..EngineConfig::default() };
        let fast = EngineConfig::default();
        let rp = search_configured(mapper.as_ref(), &space, &model, plain)
            .unwrap_or_else(|| panic!("{name}: no result (plain)"));
        let rf = search_configured(mapper.as_ref(), &space, &model, fast)
            .unwrap_or_else(|| panic!("{name}: no result (fast)"));
        assert_eq!(rp.mapping, rf.mapping, "{name}: accelerations changed the winner");
        assert_eq!(rp.score, rf.score, "{name}: accelerations changed the score");
    }
}

#[test]
fn network_orchestration_is_thread_count_invariant() {
    // the whole network-level pipeline (dedup -> session -> re-expand)
    // must inherit the engine's determinism: byte-identical reports at
    // 1 and N threads
    use union::frontend;
    use union::network::{NetworkOrchestrator, OrchestratorConfig};

    let graph = frontend::resnet50_full(1);
    let arch = presets::edge();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::default();
    let run = |threads: Option<usize>| {
        let config = OrchestratorConfig {
            samples: 120,
            seed: 13,
            threads,
            ..OrchestratorConfig::default()
        };
        NetworkOrchestrator::with_config(&arch, &model, &cons, config)
            .run(&graph)
            .expect("network maps")
    };
    let r1 = run(Some(1));
    let rn = run(Some(8));
    assert_eq!(r1.stats.distinct_jobs, rn.stats.distinct_jobs);
    assert_eq!(r1.stats.engine, rn.stats.engine, "engine stats depend on threads");
    assert_eq!(r1.total_cycles, rn.total_cycles);
    assert_eq!(r1.total_energy_j, rn.total_energy_j);
    assert_eq!(r1.edp(), rn.edp());
    for (a, b) in r1.layers.iter().zip(&rn.layers) {
        assert_eq!(a.result.mapping, b.result.mapping, "{}: mapping depends on threads", a.name);
        assert_eq!(a.result.score, b.result.score, "{}", a.name);
        assert_eq!(a.job, b.job);
        assert_eq!(a.dedup_hit, b.dedup_hit);
    }
    // the strongest form: the rendered artifacts are byte-identical
    assert_eq!(
        r1.per_layer_table().render(),
        rn.per_layer_table().render(),
        "per-layer report depends on thread count"
    );
    assert_eq!(r1.summary(), rn.summary());
}

#[test]
fn sparse_model_is_thread_count_invariant_too() {
    // sparse search runs through the same packed engine (lean path,
    // pruning, memoization), so it inherits the determinism contract
    use union::cost::CostKind;
    let p = gemm(32, 32, 32);
    let a = presets::edge();
    let c = Constraints::default();
    let space = MapSpace::new(&p, &a, &c);
    let model = CostKind::sparse_analytical(0.3, 0.05).unwrap().model();
    let mapper = RandomMapper::new(600, 23);
    let r1 = search_configured(
        &mapper,
        &space,
        model,
        EngineConfig { threads: Some(1), ..EngineConfig::default() },
    )
    .unwrap();
    let rn = search_configured(
        &mapper,
        &space,
        model,
        EngineConfig { threads: Some(6), ..EngineConfig::default() },
    )
    .unwrap();
    assert_eq!(r1.mapping, rn.mapping);
    assert_eq!(r1.score, rn.score);
    assert_eq!(r1.evaluated, rn.evaluated, "sparse scored count depends on threads");
}

#[test]
fn maestro_model_is_thread_count_invariant_too() {
    use union::cost::MaestroModel;
    let p = gemm(32, 32, 32);
    let a = presets::edge();
    let c = Constraints::default();
    let space = MapSpace::new(&p, &a, &c);
    let model = MaestroModel::new(EnergyTable::default_8bit());
    let mapper = RandomMapper::new(600, 23);
    let r1 = search_configured(
        &mapper,
        &space,
        &model,
        EngineConfig { threads: Some(1), ..EngineConfig::default() },
    )
    .unwrap();
    let rn = search_configured(
        &mapper,
        &space,
        &model,
        EngineConfig { threads: Some(6), ..EngineConfig::default() },
    )
    .unwrap();
    assert_eq!(r1.mapping, rn.mapping);
    assert_eq!(r1.score, rn.score);
}
