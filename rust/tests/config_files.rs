//! Integration: the shipped example config files parse and drive a
//! search through the CLI-level plumbing (the paper's Fig. 2 input set:
//! architecture file + mapping constraint file).

use union::arch::arch_from_str;
use union::cost::{AnalyticalModel, EnergyTable};
use union::mappers::{Mapper, RandomMapper};
use union::mapspace::{constraints_from_str, MapSpace};

#[test]
fn shipped_uarch_files_parse_and_match_presets() {
    let cloud = arch_from_str(&std::fs::read_to_string("configs/cloud_32x64.uarch").unwrap())
        .unwrap();
    assert_eq!(cloud.num_pes(), 2048);
    assert_eq!(cloud.pe_array_shape(), (64, 32));
    let edge = arch_from_str(&std::fs::read_to_string("configs/edge_16x16.uarch").unwrap())
        .unwrap();
    assert_eq!(edge.num_pes(), 256);
    // structurally identical to the presets
    let preset = union::arch::presets::cloud(32, 64);
    assert_eq!(cloud.levels.len(), preset.levels.len());
    for (a, b) in cloud.levels.iter().zip(&preset.levels) {
        assert_eq!(a.sub_clusters, b.sub_clusters);
        assert_eq!(a.is_virtual(), b.is_virtual());
    }
}

#[test]
fn nvdla_constraint_file_restricts_parallel_dims() {
    let cons = constraints_from_str(
        &std::fs::read_to_string("configs/nvdla_style.ucon").unwrap(),
    )
    .unwrap();
    assert_eq!(cons.parallel_dims.as_ref().unwrap(), &["C", "K"]);
    assert!(cons.fixed_order_for(1).is_some());

    // NVDLA-style search on a conv layer: only C/K fan out
    let p = union::problem::conv2d(1, 16, 16, 14, 14, 3, 3, 1);
    let arch = union::arch::presets::edge();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    if let Some(r) = RandomMapper::new(2_000, 3).search(&space, &model) {
        let c = p.dim_index("C").unwrap();
        let k = p.dim_index("K").unwrap();
        for l in 0..arch.depth() {
            for d in 0..p.dims.len() {
                if d != c && d != k {
                    assert_eq!(r.mapping.parallelism(l, d), 1, "dim {d} level {l}");
                }
            }
        }
    }
}

#[test]
fn memory_target_ucon_matches_builtin_preset() {
    let cons = constraints_from_str(
        &std::fs::read_to_string("configs/memory_target.ucon").unwrap(),
    )
    .unwrap();
    assert_eq!(cons.max_parallel_dims_per_level, Some(1));
}

#[test]
fn cli_parses_uarch_files() {
    let arch = union::cli::parse_arch("configs/cloud_32x64.uarch").unwrap();
    assert_eq!(arch.num_pes(), 2048);
}
