//! Integration: the full frontend→IR→problem→mapspace→cost pipeline, end
//! to end over the paper's workload zoo.

use union::cost::{AnalyticalModel, CostModel, EnergyTable, MaestroModel};
use union::frontend::{self, im2col_gemm, ttgt_gemm};
use union::ir::{check_loop_level, check_operation_level, Conformability};
use union::mapping::Mapping;
use union::prelude::*;

#[test]
fn every_table_iv_workload_lowers_and_extracts() {
    for w in frontend::dnn_workloads() {
        let affine = w.lower(false);
        assert!(
            check_loop_level(&affine).is_ok(),
            "{} must be loop-level conformable",
            w.name
        );
        let p = w.problem_via_ir(false).unwrap();
        assert_eq!(p.total_macs(), w.problem().total_macs(), "{}", w.name);
    }
}

#[test]
fn every_tc_workload_lowers_both_ways() {
    for (_, _, w) in frontend::tc_workloads() {
        // native: TC with all indices
        let native = w.problem_via_ir(false).unwrap();
        assert_eq!(native.operation, Operation::TensorContraction);
        // ttgt: collapses to GEMM with the Table III dims
        let ttgt_p = w.problem_via_ir(true).unwrap();
        assert_eq!(ttgt_p.operation, Operation::Gemm);
        assert_eq!(ttgt_p.total_macs(), native.total_macs(), "{}", w.name);
        let plan = ttgt_gemm(&w).unwrap();
        assert_eq!(ttgt_p.dims[0].size, plan.m);
    }
}

#[test]
fn conformability_routes_problems_to_models() {
    let arch = union::arch::presets::edge();
    let analytical = AnalyticalModel::new(EnergyTable::default_8bit());
    let maestro = MaestroModel::new(EnergyTable::default_8bit());

    // GEMM: both models accept
    let gemm = frontend::dlrm_layers().remove(0).problem();
    assert!(analytical.conformable(&gemm, &arch).is_ok());
    assert!(maestro.conformable(&gemm, &arch).is_ok());

    // native TC: analytical only (maestro needs the TTGT rewrite first)
    let tc_w = frontend::tccg_problem(&frontend::TCCG[0], 16);
    let tc = tc_w.problem();
    assert!(analytical.conformable(&tc, &arch).is_ok());
    assert!(maestro.conformable(&tc, &arch).is_err());
    let rewritten = ttgt_gemm(&tc_w).unwrap().gemm_workload("tc_ttgt").problem();
    assert!(maestro.conformable(&rewritten, &arch).is_ok());

    // the IR-level conformability passes agree with the model-level ones
    let affine_native = tc_w.lower(false);
    match check_operation_level(&affine_native, MaestroModel::supported_operations()) {
        Conformability::NotConformable(_) => {}
        other => panic!("expected not-conformable, got {other:?}"),
    }
    let affine_ttgt = tc_w.lower(true);
    assert!(check_operation_level(&affine_ttgt, MaestroModel::supported_operations()).is_ok());
}

#[test]
fn im2col_and_native_conv_agree_on_macs_and_search() {
    let conv = frontend::resnet50_layers().remove(0);
    let gemm = im2col_gemm(&conv).unwrap();
    assert_eq!(conv.macs(), gemm.macs());

    // both can be searched on the edge accelerator
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for w in [&conv, &gemm] {
        let p = w.problem();
        let space = MapSpace::new(&p, &arch, &cons);
        let r = RandomMapper::new(400, 5).search(&space, &model);
        assert!(r.is_some(), "{} search failed", w.name);
    }
}

#[test]
fn full_pipeline_from_config_files() {
    // architecture + constraints from text, workload from the zoo —
    // exactly the paper's Fig. 2 input set
    let arch = union::arch::arch_from_str(
        "name: custom\nnoc_bw: 32\nclusters:\n  - name: C4\n    memory: DRAM\n    sub_clusters: 1\n  - name: C3\n    memory: L2\n    size_kb: 100\n    sub_clusters: 16\n    axis: Y\n  - name: C2\n    virtual: true\n    sub_clusters: 16\n    axis: X\n  - name: C1\n    memory: L1\n    size_kb: 0.5\n    sub_clusters: 1\n",
    )
    .unwrap();
    assert_eq!(arch.num_pes(), 256);
    let cons = union::mapspace::constraints_from_str(
        "parallel_dims: [M, N]\nmin_utilization: 0.1\n",
    )
    .unwrap();
    let p = frontend::gemm_problem(256, 256, 256);
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let best = RandomMapper::new(2_000, 9).search(&space, &model).expect("search");
    // constraints respected
    assert!(best.cost.utilization >= 0.1);
    let k = p.dim_index("K").unwrap();
    for l in 0..arch.depth() {
        assert_eq!(best.mapping.parallelism(l, k), 1);
    }
}

#[test]
fn sequential_baseline_always_evaluable_on_fig5_toy() {
    let arch = union::arch::presets::fig5_toy();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let p = frontend::gemm_problem(8, 8, 8);
    let m = Mapping::sequential(&p, &arch);
    let e = model.evaluate(&p, &arch, &m).unwrap();
    assert_eq!(e.macs, 512);
    assert!(e.cycles >= 512.0);
}

#[test]
fn mttkrp_unit_op_gate_end_to_end() {
    // §III-B.2: MTTKRP is rejected by a 2-operand-configured model and
    // accepted once the unit op is 3-operand
    let p = union::problem::mttkrp(16, 16, 16, 16);
    let arch = union::arch::presets::edge();
    let two = AnalyticalModel::new(EnergyTable::default_8bit());
    assert!(two.conformable(&p, &arch).is_err());
    let three = AnalyticalModel::new(EnergyTable::default_8bit()).with_unit_op_operands(3);
    assert!(three.conformable(&p, &arch).is_ok());
    let cons = Constraints::default();
    let space = MapSpace::new(&p, &arch, &cons);
    let r = RandomMapper::new(500, 3).search(&space, &three);
    assert!(r.is_some());
}
