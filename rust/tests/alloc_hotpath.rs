//! Zero-allocation contract of the steady-state evaluate loop.
//!
//! A counting global allocator wraps `System` and tallies every
//! `alloc`/`realloc`. After a warm-up pass over a fixed GEMM batch, a
//! repeat of the same batch must perform **zero heap allocations**:
//!
//! * with memoization on, every candidate resolves from the interned
//!   evaluation memo (fingerprint lookup, no key construction);
//! * with memoization off, every candidate re-runs the full pipeline —
//!   packed decode into a reused `Mapping`, legality via the bitmask
//!   check, lower-bound pruning, and `evaluate_lean` into the worker's
//!   `TileScratch` — still without touching the allocator.
//!
//! This file intentionally holds a single `#[test]`: the allocation
//! counter is process-global, and a concurrently running test would
//! pollute the steady-state window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_evaluate_loop_is_allocation_free() {
    use union::arch::presets;
    use union::cost::{AnalyticalModel, EnergyTable};
    use union::engine::{Engine, EngineConfig};
    use union::mappers::Objective;
    use union::mapping::PackedBatch;
    use union::mapspace::{Constraints, MapSpace};
    use union::problem::gemm;
    use union::util::rng::Rng;

    let problem = gemm(32, 32, 32);
    let arch = presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&problem, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());

    // a fixed batch of packed candidates, written once up front
    let (nl, nd) = space.packed_shape();
    let mut batch = PackedBatch::new();
    batch.reset(nl, nd);
    let mut rng = Rng::new(99);
    for _ in 0..256 {
        batch.push_with(|slot| space.sample_into(&mut rng, slot));
    }

    // threads=1 keeps the loop on the calling thread: scoped-thread
    // spawning is a per-batch (not per-candidate) cost and would show
    // up in the counter without being part of the per-candidate story
    let single = |memoize: bool| EngineConfig {
        threads: Some(1),
        memoize,
        ..EngineConfig::default()
    };

    // ---- memo-hit steady state (memoization on) ----
    let mut engine = Engine::with_config(&space, &model, Objective::Edp, single(true));
    engine.evaluate_packed(&batch); // warm: memo interning, incumbent, buffers
    engine.evaluate_packed(&batch); // settle every buffer capacity
    let scored_warm = engine.stats().scored;
    let before = allocations();
    let scored = engine.evaluate_packed(&batch);
    let after = allocations();
    assert!(scored > 0, "fixed batch must keep scoring");
    assert_eq!(
        engine.stats().scored,
        scored_warm + scored,
        "repeat batch must score the same candidates"
    );
    assert_eq!(
        after - before,
        0,
        "memo-hit steady state allocated {} times for {} candidates",
        after - before,
        batch.len()
    );

    // ---- full-evaluation steady state (memoization off) ----
    // every candidate re-runs decode + legality + bound + evaluate_lean
    let mut engine = Engine::with_config(&space, &model, Objective::Edp, single(false));
    engine.evaluate_packed(&batch); // warm: incumbent + full estimate, scratch sizing
    engine.evaluate_packed(&batch); // settle buffer capacities
    let evals_before = engine.stats().cost_evals;
    let before = allocations();
    let scored = engine.evaluate_packed(&batch);
    let after = allocations();
    assert!(scored > 0);
    assert!(
        engine.stats().cost_evals > evals_before,
        "memoization off: the cost model must actually run"
    );
    assert_eq!(
        after - before,
        0,
        "full-evaluation steady state allocated {} times for {} candidates",
        after - before,
        batch.len()
    );

    // ---- sparse wrapper steady state (both memo modes) ----
    // the sparsity kind rides the same packed pipeline: per-problem
    // density scales must be derived without touching the allocator
    use union::cost::CostKind;
    let sparse = CostKind::sparse_analytical(0.3, 0.05).unwrap().model();
    for memoize in [true, false] {
        let mut engine = Engine::with_config(&space, sparse, Objective::Edp, single(memoize));
        engine.evaluate_packed(&batch); // warm
        engine.evaluate_packed(&batch); // settle
        let before = allocations();
        let scored = engine.evaluate_packed(&batch);
        let after = allocations();
        assert!(scored > 0, "sparse batch must keep scoring (memoize={memoize})");
        assert_eq!(
            after - before,
            0,
            "sparse steady state (memoize={memoize}) allocated {} times for {} candidates",
            after - before,
            batch.len()
        );
    }

    // ---- telemetry recording (the observability add-on) ----
    // registration allocates (name interning, leaked cells) and is done
    // once, up front; recording into the returned handles is the part
    // that rides the hot path and must be allocation-free — this is the
    // "zero allocation on record" invariant in `telemetry/mod.rs`
    let counter = union::telemetry::counter("alloc_test_counter");
    let gauge = union::telemetry::gauge("alloc_test_gauge");
    let hist = union::telemetry::histogram("alloc_test_hist");
    counter.incr(); // warm (nothing to warm, but symmetric with above)
    hist.record(17);
    let before = allocations();
    for i in 0..batch.len() as u64 {
        counter.add(i);
        gauge.set(i);
        hist.record(i * 37);
    }
    let after = allocations();
    assert!(counter.get() > 0 && hist.snapshot().count > 0);
    assert_eq!(
        after - before,
        0,
        "telemetry recording allocated {} times for {} observations",
        after - before,
        batch.len()
    );

    // ---- transfer surrogate scoring (the ranked path's add-on) ----
    // a RankedSource adds exactly one SurrogateRanker::score call per
    // candidate on top of the evaluate loop asserted above; that score
    // is pure arithmetic over the ranker's packed neighbor codes and
    // must never touch the allocator
    use union::transfer::SurrogateRanker;
    let mut rng = Rng::new(7);
    let neighbor = space.sample_legal(&mut rng, 10_000).expect("a legal neighbor exists");
    let ranker = SurrogateRanker::from_neighbors(&space, &[(neighbor, 1.0, 0.25)])
        .expect("one neighbor builds a ranker");
    let mut acc = 0.0f64;
    for i in 0..batch.len() {
        acc += ranker.score(batch.get(i)); // warm (and defeat dead-code elim)
    }
    let before = allocations();
    for i in 0..batch.len() {
        acc += ranker.score(batch.get(i));
    }
    let after = allocations();
    assert!(acc.is_finite(), "surrogate scores must stay finite");
    assert_eq!(
        after - before,
        0,
        "surrogate scoring allocated {} times for {} candidates",
        after - before,
        batch.len()
    );
}
