//! Integration: the paper's interoperability claim — **every mapper works
//! with every cost model** through the unified abstractions (Table I's
//! "Unified" mappers row). 5 mappers × 2 cost models × 2 workload classes.

use union::cost::{AnalyticalModel, EnergyTable, MaestroModel};
use union::frontend;
use union::mappers::{
    DecoupledMapper, ExhaustiveMapper, GeneticMapper, HeuristicMapper, Mapper, Objective,
    RandomMapper,
};
use union::mapspace::{Constraints, MapSpace};

fn mappers() -> Vec<Box<dyn Mapper>> {
    vec![
        Box::new(ExhaustiveMapper::new(20_000)),
        Box::new(RandomMapper::new(400, 11)),
        Box::new(DecoupledMapper::new(120, 40, 11)),
        Box::new(HeuristicMapper::new(200, 40, 11)),
        Box::new(GeneticMapper::new(30, 4, 11)),
    ]
}

#[test]
fn all_mappers_drive_analytical_on_gemm() {
    let p = frontend::gemm_problem(64, 64, 64);
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for mapper in mappers() {
        let r = mapper
            .search(&space, &model)
            .unwrap_or_else(|| panic!("{} found nothing", mapper.name()));
        assert!(space.admits(&r.mapping), "{}", mapper.name());
        assert!(r.score.is_finite() && r.score > 0.0, "{}", mapper.name());
        assert!(r.evaluated > 0, "{}", mapper.name());
    }
}

#[test]
fn all_mappers_drive_maestro_on_gemm() {
    let p = frontend::gemm_problem(64, 64, 64);
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = MaestroModel::new(EnergyTable::default_8bit());
    for mapper in mappers() {
        let r = mapper
            .search(&space, &model)
            .unwrap_or_else(|| panic!("{} x maestro found nothing", mapper.name()));
        assert!(space.admits(&r.mapping), "{}", mapper.name());
    }
}

#[test]
fn all_mappers_drive_analytical_on_conv() {
    // 7-dim CONV2D exercises larger chains
    let p = union::problem::conv2d(1, 16, 16, 14, 14, 3, 3, 1);
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for mapper in mappers() {
        // exhaustive would explode on 7 dims; cap it via its limit — it
        // still must return *something* legal from the truncated space
        let r = mapper.search(&space, &model);
        assert!(r.is_some(), "{} x conv found nothing", mapper.name());
    }
}

#[test]
fn objectives_order_consistently_for_every_mapper() {
    let p = frontend::gemm_problem(32, 32, 32);
    let arch = union::arch::presets::fig5_toy();
    let cons = Constraints::default();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for mapper in mappers() {
        let lat = mapper.search_with(&space, &model, Objective::Latency);
        let nrg = mapper.search_with(&space, &model, Objective::Energy);
        if let (Some(l), Some(n)) = (lat, nrg) {
            // a latency-optimized result cannot be slower than an
            // energy-optimized one from the same search budget... only
            // guaranteed for deterministic searches over the same set;
            // assert the weaker sanity: optimizing X yields finite X
            assert!(l.cost.latency_s().is_finite());
            assert!(n.cost.energy_j().is_finite());
        }
    }
}

#[test]
fn exhaustive_is_lower_bound_on_toy_space() {
    // on a space small enough to enumerate fully, no other mapper beats
    // exhaustive — the sanity anchor for all search results
    let p = frontend::gemm_problem(8, 8, 8);
    let arch = union::arch::presets::fig5_toy();
    let cons = Constraints::default();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let exhaustive = ExhaustiveMapper::new(500_000)
        .search(&space, &model)
        .expect("exhaustive");
    for mapper in mappers().into_iter().skip(1) {
        if let Some(r) = mapper.search(&space, &model) {
            assert!(
                r.score >= exhaustive.score - 1e-18,
                "{} beat exhaustive: {} < {}",
                mapper.name(),
                r.score,
                exhaustive.score
            );
        }
    }
}

#[test]
fn memory_target_constraint_respected_by_all_mappers() {
    let p = frontend::gemm_problem(64, 64, 64);
    let arch = union::arch::presets::edge();
    let cons = Constraints::memory_target_style();
    let space = MapSpace::new(&p, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    for mapper in mappers() {
        if let Some(r) = mapper.search(&space, &model) {
            for l in 0..arch.depth() {
                let distinct = (0..p.dims.len())
                    .filter(|&d| r.mapping.parallelism(l, d) > 1)
                    .count();
                assert!(distinct <= 1, "{} violated memory-target constraint", mapper.name());
            }
        }
    }
}
