//! Transfer-guided warm starts: samples-to-incumbent on a held-out
//! workload family vs. the cold engine. With `UNION_BENCH_DIR` set, the
//! run is recorded as `BENCH_transfer_warm.json` for the
//! bench-regression gate.
//!
//! The scenario is the serving pattern the transfer layer exists for: a
//! **donor** GEMM has already been searched (its winner sits in the
//! result cache), and a **query** arrives that is the same operator at
//! a scaled size. The bench mines the donor into a [`TransferIndex`],
//! projects its winning mapping into the query's map space, and runs
//! the query twice on an identical candidate stream:
//!
//! * **cold** — the plain engine, no transfer;
//! * **warm** — the projected donor winner as a seed batch plus a
//!   [`SurrogateRanker`]-ordered stream ([`RankedSource`]).
//!
//! Both runs use a *pure* `RandomMapper` stream, which is
//! progress-independent: the warm run's candidate multiset is therefore
//! exactly the cold multiset plus the seed, so its final incumbent is
//! provably never worse — `transfer_quality_never_worse` asserts the
//! score bits, not a tolerance. (Portfolio jobs include an
//! incumbent-reactive hill climber and are pinned to a quality
//! tolerance by the service smoke test instead.)
//!
//! Gated metrics:
//! * `transfer_cold_over_warm_samples` — scored candidates the cold run
//!   needs to reach the cold-final score, over what the warm run needs
//!   (the ISSUE target is ≥ 2×; the committed baseline is a floor seed
//!   until a verified machine re-records it);
//! * `transfer_quality_never_worse` — 1.0 iff warm final ≤ cold final
//!   in exact score bits;
//! * `transfer_disabled_bit_identical` — 1.0 iff
//!   `run_job_transferred(no seeds, no ranker)` is byte-identical to
//!   `run_job` (mapping, score bits, proposed/scored counts);
//! * `transfer_thread_invariant` — 1.0 iff the warm path returns the
//!   same score bits at 1 and 4 evaluation threads.

use std::cell::Cell;
use std::rc::Rc;

use union::arch::presets;
use union::cost::{AnalyticalModel, EnergyTable};
use union::engine::{CandidateSource, EngineConfig, Progress, Session};
use union::mappers::{Mapper, Objective, RandomMapper};
use union::mapping::{Mapping, PackedBatch};
use union::mapspace::{Constraints, MapSpace};
use union::problem::{gemm, Problem};
use union::transfer::{
    project_mapping, RankedSource, SurrogateRanker, TransferIndex, DEFAULT_TOP_K,
};
use union::util::bench::Bencher;

const SAMPLES: usize = 600;
const SEED: u64 = 42;

/// Canonical-signature rendering for a dense analytical EDP job (the
/// exact shape `job_signature` in `service/broker.rs` produces; the
/// round-trip against the real broker is pinned by its unit tests).
fn sig(p: &Problem, samples: usize, seed: u64) -> String {
    format!(
        "union-job-v1|{}|arch=edge#00deadbeef00cafe|model=analytical|cons=|obj=edp|samples={samples}|seed={seed}",
        p.signature()
    )
    .replace('\n', ";")
}

/// Transparent pass-through source that counts scored candidates (via
/// each batch's `Progress::last_scored`) and records how many had been
/// scored when the incumbent first reached `target`. Ordering,
/// batching and termination are forwarded untouched, so wrapping a
/// source in a `Tracked` cannot change the search result.
struct Tracked {
    inner: Box<dyn CandidateSource>,
    target: f64,
    scored: Rc<Cell<u64>>,
    hit_at: Rc<Cell<Option<u64>>>,
}

impl CandidateSource for Tracked {
    fn name(&self) -> &str {
        "tracked"
    }

    fn preadmitted(&self) -> bool {
        self.inner.preadmitted()
    }

    fn next_batch(
        &mut self,
        space: &MapSpace,
        progress: &Progress,
        out: &mut PackedBatch,
    ) -> bool {
        self.scored.set(self.scored.get() + progress.last_scored.len() as u64);
        if self.hit_at.get().is_none() {
            if let Some((_, best)) = progress.best {
                if best <= self.target {
                    self.hit_at.set(Some(self.scored.get()));
                }
            }
        }
        self.inner.next_batch(space, progress, out)
    }
}

struct Run {
    score: f64,
    mapping: Mapping,
    scored: u64,
    /// Scored candidates when the incumbent first reached the target
    /// (`scored` total if only the unobserved final batch got there).
    samples_to_target: u64,
}

fn run_tracked(
    session: &mut Session,
    space: &MapSpace,
    seeds: &[Mapping],
    source: Box<dyn CandidateSource>,
    target: f64,
) -> Run {
    let scored = Rc::new(Cell::new(0u64));
    let hit_at = Rc::new(Cell::new(None));
    let mut sources: Vec<Box<dyn CandidateSource>> = vec![Box::new(Tracked {
        inner: source,
        target,
        scored: Rc::clone(&scored),
        hit_at: Rc::clone(&hit_at),
    })];
    let (r, _) = session.run_job_seeded(space, seeds, &mut sources);
    let r = r.expect("search finds a mapping");
    Run {
        score: r.score,
        mapping: r.mapping,
        scored: scored.get(),
        samples_to_target: hit_at.get().unwrap_or_else(|| scored.get()),
    }
}

fn main() {
    let mut b = Bencher::with_iters(2, 10);

    let arch = presets::edge();
    let cons = Constraints::default();
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let donor_p = gemm(64, 64, 64);
    let query_p = gemm(128, 64, 64);
    let donor_space = MapSpace::new(&donor_p, &arch, &cons);
    let query_space = MapSpace::new(&query_p, &arch, &cons);

    // ---- donor: the "already in the cache" job ----
    let mut session = Session::new(&model, Objective::Edp);
    let (donor, _) = session.run_job(
        &donor_space,
        &mut vec![RandomMapper::new(SAMPLES, SEED).source()],
    );
    let donor = donor.expect("donor search finds a mapping");

    // ---- mine the index exactly as the broker does on startup ----
    let mut index = TransferIndex::new();
    assert!(index.insert(&sig(&donor_p, SAMPLES, SEED), &donor.mapping, donor.score));
    let neighbors = index.lookup(&sig(&query_p, SAMPLES, SEED), DEFAULT_TOP_K);
    assert_eq!(neighbors.len(), 1, "the donor is the query's one neighbor");
    assert!(neighbors[0].distance.is_finite());

    let projected = project_mapping(&query_space, &neighbors[0].mapping)
        .expect("a same-family donor projects onto the query space");
    assert!(query_space.admits(&projected), "projection re-legalizes");
    let seeds = vec![projected.clone()];
    let ranker = Rc::new(
        SurrogateRanker::from_neighbors(
            &query_space,
            &[(projected, neighbors[0].score, neighbors[0].distance)],
        )
        .expect("one projected neighbor builds a ranker"),
    );

    // ---- cold reference: the target score both runs race toward ----
    let mut reference = Session::new(&model, Objective::Edp);
    let (cold_ref, _) = reference.run_job(
        &query_space,
        &mut vec![RandomMapper::new(SAMPLES, SEED).source()],
    );
    let cold_ref = cold_ref.expect("cold reference finds a mapping");
    let target = cold_ref.score;

    // ---- timed: cold vs warm on the identical candidate stream ----
    let mut cold_run = None;
    let cold_rate = b.bench_rate("transfer_cold_search", "cand", || {
        let mut s = Session::new(&model, Objective::Edp);
        let run = run_tracked(
            &mut s,
            &query_space,
            &[],
            RandomMapper::new(SAMPLES, SEED).source(),
            target,
        );
        let scored = run.scored.max(1);
        cold_run = Some(run);
        scored
    });
    let cold = cold_run.expect("cold bench ran");
    assert_eq!(
        cold.score.to_bits(),
        cold_ref.score.to_bits(),
        "the tracking wrapper must be transparent"
    );

    let mut warm_run = None;
    let warm_rate = b.bench_rate("transfer_warm_search", "cand", || {
        let mut s = Session::new(&model, Objective::Edp);
        let run = run_tracked(
            &mut s,
            &query_space,
            &seeds,
            Box::new(RankedSource::new(
                RandomMapper::new(SAMPLES, SEED).source(),
                Rc::clone(&ranker),
            )),
            target,
        );
        let scored = run.scored.max(1);
        warm_run = Some(run);
        scored
    });
    let warm = warm_run.expect("warm bench ran");

    // the warm multiset is the cold multiset plus the seed batch, so on
    // this progress-independent stream the warm incumbent is *exactly*
    // never worse — score bits, not a tolerance
    assert!(
        warm.score <= cold.score,
        "warm incumbent regressed: {} vs cold {}",
        warm.score,
        cold.score
    );
    // the seed batch itself counts against the warm run's budget
    let warm_samples = warm.samples_to_target + seeds.len() as u64;
    let speedup = cold.samples_to_target as f64 / warm_samples.max(1) as f64;

    // ---- advisory invariant: no ranker + no seeds == run_job ----
    let mut plain = Session::new(&model, Objective::Edp);
    let (a, sa) = plain.run_job(
        &query_space,
        &mut vec![RandomMapper::new(SAMPLES, SEED).source()],
    );
    let mut off = Session::new(&model, Objective::Edp);
    let (z, sz) = off.run_job_transferred(
        &query_space,
        &[],
        None,
        vec![RandomMapper::new(SAMPLES, SEED).source()],
    );
    let (a, z) = (a.unwrap(), z.unwrap());
    assert_eq!(a.mapping, z.mapping, "transfer off must be run_job, exactly");
    assert_eq!(a.score.to_bits(), z.score.to_bits());
    assert_eq!(sa.proposed, sz.proposed);
    assert_eq!(sa.scored, sz.scored);

    // ---- determinism: warm path is thread-count-invariant ----
    let mut by_threads = Vec::new();
    for threads in [1usize, 4] {
        let mut s = Session::with_config(
            &model,
            Objective::Edp,
            EngineConfig { threads: Some(threads), ..EngineConfig::default() },
        );
        let (r, _) = s.run_job_transferred(
            &query_space,
            &seeds,
            Some(Rc::clone(&ranker)),
            vec![RandomMapper::new(SAMPLES, SEED).source()],
        );
        by_threads.push(r.unwrap().score.to_bits());
    }
    assert_eq!(by_threads[0], by_threads[1], "warm path must be thread-invariant");
    assert_eq!(by_threads[0], warm.score.to_bits());

    println!(
        "transfer warm-start: cold {} samples to incumbent, warm {} ({:.1}x); \
         cold {:.3e} cand/s, warm {:.3e} cand/s; final {:.4e} (cold {:.4e})",
        cold.samples_to_target, warm_samples, speedup, cold_rate, warm_rate, warm.score, cold.score
    );
    if warm.mapping != cold.mapping {
        println!("warm winner differs from cold winner (seed win at equal-or-better score)");
    }

    b.gated_metric("transfer_cold_over_warm_samples", speedup);
    b.gated_metric("transfer_quality_never_worse", 1.0);
    b.gated_metric("transfer_disabled_bit_identical", 1.0);
    b.gated_metric("transfer_thread_invariant", 1.0);
    b.metric("transfer_cold_samples_to_incumbent", cold.samples_to_target as f64);
    b.metric("transfer_warm_samples_to_incumbent", warm_samples as f64);
    b.metric("transfer_index_neighbors", neighbors.len() as f64);
    b.write_json_env("transfer_warm");
}
