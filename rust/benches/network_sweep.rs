//! Regenerates the Table IV-style network-level co-design sweep (full
//! ResNet-50 + the DLRM/BERT FC stacks on edge and cloud) and reports
//! the cross-layer dedup the orchestrator achieved. The acceptance
//! check for the network path lives here: the distinct-job count must
//! be strictly below the layer count on ResNet-50.

use union::experiments::{network_sweep, Effort};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 3);
    let (table, results) = b.bench("network_sweep(fast)", || network_sweep(Effort::Fast));
    print!("{}", table.render());
    for r in &results {
        println!("{}", r.summary());
    }
    let resnet = results
        .iter()
        .find(|r| r.network == "ResNet50")
        .expect("sweep covers ResNet-50");
    assert!(
        resnet.stats.distinct_jobs < resnet.stats.layers as usize,
        "cross-layer dedup must evaluate fewer jobs ({}) than layers ({})",
        resnet.stats.distinct_jobs,
        resnet.stats.layers,
    );
    println!(
        "resnet50 dedup: {} layers -> {} distinct jobs ({:.1}% reuse)",
        resnet.stats.layers,
        resnet.stats.distinct_jobs,
        100.0 * resnet.stats.dedup_hit_rate
    );
}
