//! Regenerates the Table IV-style network-level co-design sweep (full
//! ResNet-50 + the DLRM/BERT FC stacks on edge and cloud) and reports
//! the cross-layer dedup the orchestrator achieved. The acceptance
//! check for the network path lives here: the distinct-job count must
//! be strictly below the layer count on ResNet-50. With
//! `UNION_BENCH_DIR` set, the run is recorded as
//! `BENCH_network_sweep.json` for the bench-regression gate.

use union::experiments::{network_sweep, Effort};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 3);
    let mut last = None;
    b.bench_rate("network_sweep(fast)", "cand", || {
        let (table, results) = network_sweep(Effort::Fast);
        let proposed: u64 = results.iter().map(|r| r.stats.engine.proposed as u64).sum();
        last = Some((table, results));
        proposed
    });
    let (table, results) = last.expect("bench ran at least once");
    print!("{}", table.render());
    for r in &results {
        println!("{}", r.summary());
    }
    let resnet = results
        .iter()
        .find(|r| r.network == "ResNet50")
        .expect("sweep covers ResNet-50");
    assert!(
        resnet.stats.distinct_jobs < resnet.stats.layers as usize,
        "cross-layer dedup must evaluate fewer jobs ({}) than layers ({})",
        resnet.stats.distinct_jobs,
        resnet.stats.layers,
    );
    println!(
        "resnet50 dedup: {} layers -> {} distinct jobs ({:.1}% reuse)",
        resnet.stats.layers,
        resnet.stats.distinct_jobs,
        100.0 * resnet.stats.dedup_hit_rate
    );
    b.gated_metric("resnet50_dedup_hit_rate", resnet.stats.dedup_hit_rate);
    b.metric("networks_swept", results.len() as f64);
    b.write_json_env("network_sweep");
}
