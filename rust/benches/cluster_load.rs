//! Cluster serving bench: zipfian clients against a live two-process
//! (two-server) rendezvous-routed cluster, exercising the full
//! multi-process story end to end — deterministic routing, failover
//! when a member dies, and snapshot `sync` re-warming a restarted
//! member. With `UNION_BENCH_DIR` set, the run is recorded as
//! `BENCH_cluster_load.json` for the bench-regression gate.
//!
//! The narrative, in order:
//!   1. two servers partition a zipfian job pool by signature; clients
//!      route each request to its owner (timed: aggregate req/s);
//!   2. member B shuts down; B-owned jobs fail over to A and are still
//!      answered bit-identically to a direct orchestrator run;
//!   3. B restarts on the same address with an empty cache, imports
//!      A's snapshot via `sync`, and the cluster returns to all-warm
//!      serving — the restarted member searches **nothing** (the gated
//!      restart warm-hit rate).

use std::time::Instant;

use union::mappers::Objective;
use union::service::{
    client_request, job_signature, resolve_spec, sync_from_peer, Cluster, ClusterClient,
    JobSpec, Json, Request, ResultCache, ServeConfig, Server,
};
use union::util::bench::Bencher;
use union::util::stats::Summary;
use union::util::Rng;

/// Distinct jobs in the pool (zipf ranks).
const POOL: usize = 8;
/// Concurrent client threads.
const CLIENTS: usize = 4;
/// Requests each client issues per timed iteration.
const REQS_PER_CLIENT: usize = 30;
/// Search samples per job — tiny on purpose: the bench measures the
/// serving and routing overheads, not search time.
const SAMPLES: usize = 60;
/// Zipf exponent: rank r is drawn with weight 1/r^s.
const ZIPF_EXPONENT: f64 = 1.1;

/// Pool rank `i` with an explicit seed: the seed is scanned at startup
/// so each rank's signature lands on the desired member (the member
/// addresses carry ephemeral ports, so ownership cannot be fixed at
/// compile time without fixing the seeds at run time).
fn spec_with(i: usize, seed: u64) -> JobSpec {
    let dims = [16, 24, 32, 40, 48, 64, 80, 96];
    JobSpec {
        workload: format!("gemm:{}x16x16", dims[i % dims.len()]),
        arch: "edge".into(),
        cost: "analytical".into(),
        objective: Objective::Edp,
        samples: SAMPLES,
        seed,
        constraints: String::new(),
    }
}

fn request_with(i: usize, seed: u64) -> Request {
    Request::Search { id: None, spec: spec_with(i, seed), progress: false }
}

/// Cumulative zipf distribution over the pool ranks.
fn zipf_cumulative() -> [f64; POOL] {
    let mut w = [0.0; POOL];
    let mut total = 0.0;
    for (r, slot) in w.iter_mut().enumerate() {
        *slot = 1.0 / ((r + 1) as f64).powf(ZIPF_EXPONENT);
        total += *slot;
    }
    let mut acc = 0.0;
    for slot in w.iter_mut() {
        acc += *slot / total;
        *slot = acc;
    }
    w[POOL - 1] = 1.0;
    w
}

fn pick(rng: &mut Rng, cum: &[f64; POOL]) -> usize {
    let u = rng.f64();
    cum.iter().position(|&c| u < c).unwrap_or(POOL - 1)
}

fn bind_server(port: u16, cache: Option<std::path::PathBuf>) -> (Server, String) {
    let server = Server::bind(ServeConfig { port, cache, ..ServeConfig::default() })
        .expect("bind server");
    let addr = server.local_addr().expect("local addr").to_string();
    (server, addr)
}

fn status(addr: &str) -> Json {
    client_request(addr, &Request::Status { id: None }).expect("status served")
}

fn shutdown(addr: &str) {
    let bye = client_request(addr, &Request::Shutdown { id: None }).expect("shutdown served");
    assert_eq!(bye.bool_field("ok"), Some(true));
}

/// One timed load phase: `CLIENTS` threads issuing zipf-distributed
/// requests, each routed client-side to its owner (both members up, so
/// plain owner routing needs no failover state). Returns latencies.
fn run_phase(owners: &[String; POOL], seeds: [u64; POOL], phase_seed: u64) -> Vec<f64> {
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let owners = owners.clone();
            std::thread::spawn(move || {
                let mut rng =
                    Rng::new(phase_seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(c as u64 + 1));
                let cum = zipf_cumulative();
                let mut lat = Vec::with_capacity(REQS_PER_CLIENT);
                for _ in 0..REQS_PER_CLIENT {
                    let i = pick(&mut rng, &cum);
                    let t0 = Instant::now();
                    let resp = client_request(&owners[i], &request_with(i, seeds[i]))
                        .expect("request served");
                    lat.push(t0.elapsed().as_secs_f64());
                    assert_eq!(
                        resp.str("type"),
                        Some("result"),
                        "unexpected response under load: {}",
                        resp.to_line()
                    );
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::with_capacity(CLIENTS * REQS_PER_CLIENT);
    for h in handles {
        all.extend(h.join().expect("client thread"));
    }
    all
}

fn main() {
    let (server_a, a_addr) = bind_server(0, None);
    let (server_b, b_addr) = bind_server(0, None);
    let b_port: u16 = b_addr.rsplit(':').next().unwrap().parse().unwrap();
    let a_daemon = std::thread::spawn(move || server_a.run());
    let b_daemon = std::thread::spawn(move || server_b.run());

    let members = vec![a_addr.clone(), b_addr.clone()];
    let cluster = Cluster::new(members.clone()).expect("cluster");
    let a_idx = members.iter().position(|m| m == &a_addr).unwrap();
    let b_idx = 1 - a_idx;

    // balance the pool by construction: scan each rank's seed until it
    // hashes to the desired member, alternating A/B — so both members
    // always own ranks regardless of which ephemeral ports they got
    let mut seeds = [0u64; POOL];
    for (i, slot) in seeds.iter_mut().enumerate() {
        let want = if i % 2 == 0 { a_idx } else { b_idx };
        *slot = (42..42 + 512u64)
            .find(|&s| {
                let sig = job_signature(&resolve_spec(&spec_with(i, s)).expect("spec"));
                cluster.owner(&sig) == want
            })
            .expect("a seed in 42..554 lands on the desired owner");
    }
    // pre-resolve each pool job's signature and owner address, so the
    // timed loop routes with a table lookup (what a warmed client does)
    let sigs: Vec<String> = (0..POOL)
        .map(|i| job_signature(&resolve_spec(&spec_with(i, seeds[i])).expect("spec resolves")))
        .collect();
    let owner_idx: Vec<usize> = sigs.iter().map(|s| cluster.owner(s)).collect();
    let owners: [String; POOL] =
        std::array::from_fn(|i| members[owner_idx[i]].clone());

    // routing determinism: a client holding the member list in any
    // order must pick the same owner for every signature
    let shuffled = Cluster::new(vec![b_addr.clone(), a_addr.clone()]).expect("cluster");
    let routing_deterministic = sigs
        .iter()
        .all(|s| shuffled.members()[shuffled.owner(s)] == members[cluster.owner(s)]);

    // warm each owner with its own partition
    for i in 0..POOL {
        let r = client_request(&owners[i], &request_with(i, seeds[i])).expect("warmup served");
        assert_eq!(r.str("type"), Some("result"), "{}", r.to_line());
    }

    // bit-identity probe (before the timed window): the routed answer
    // equals a direct orchestrator run of the same job
    let served =
        client_request(&owners[0], &request_with(0, seeds[0])).expect("identity probe served");
    let mapping =
        union::service::mapping_from_json(served.get("mapping").expect("mapping present"))
            .expect("mapping decodes");
    let job = resolve_spec(&spec_with(0, seeds[0])).expect("spec resolves");
    let direct = {
        use union::network::{NetworkOrchestrator, OrchestratorConfig, WorkloadGraph};
        let graph = WorkloadGraph::from_workloads("direct", vec![job.workload.clone()]);
        let orch = NetworkOrchestrator::with_config(
            &job.arch,
            job.cost.model(),
            &job.constraints,
            OrchestratorConfig {
                objective: job.objective,
                samples: job.samples,
                seed: job.seed,
                threads: Some(1),
            },
        );
        orch.run(&graph).expect("direct run")
    };
    let direct_best = &direct.layers[0].result;
    assert_eq!(mapping, direct_best.mapping, "served mapping differs from direct run");
    let mut bit_identical = served.num("score").expect("score").to_bits()
        == direct_best.score.to_bits();

    // phase 1 (timed): aggregate req/s with both members serving their
    // partitions warm
    let mut b = Bencher::with_iters(1, 3);
    let mut latencies: Vec<f64> = Vec::new();
    let mut phase = 0u64;
    let rps = b.bench_rate("cluster_load_requests", "req", || {
        phase += 1;
        latencies.extend(run_phase(&owners, seeds, 0xC1A5 + phase));
        (CLIENTS * REQS_PER_CLIENT) as u64
    });
    let lat = Summary::of(&latencies);

    // phase 2: kill B; every job fails over to A and is still answered
    // (B-owned jobs cost A a fresh search — correctness over latency)
    shutdown(&b_addr);
    b_daemon.join().expect("server B thread").expect("server B exits cleanly");
    let mut cc = ClusterClient::new(cluster.clone(), 0xFA11);
    let mut failovers = 0usize;
    for i in 0..POOL {
        let (answered_by, doc) =
            cc.request(&sigs[i], &request_with(i, seeds[i])).expect("failover served");
        assert_eq!(doc.str("type"), Some("result"), "{}", doc.to_line());
        assert_eq!(answered_by, a_idx, "only A is alive to answer");
        if owner_idx[i] == b_idx {
            failovers += 1;
            // the re-routed answer must carry the same bits the owner
            // served during the warm phase (same job, same seed)
            bit_identical &= doc.num("score").expect("score").to_bits()
                == client_request(&a_addr, &request_with(i, seeds[i]))
                    .expect("repeat served")
                    .num("score")
                    .expect("score")
                    .to_bits();
        }
    }
    assert_eq!(failovers, POOL / 2, "the seed scan alternates owners");

    // phase 3: B restarts on its old address with an empty cache and
    // re-warms from A's snapshot instead of re-searching
    let sync_cache = {
        let mut dir = std::env::temp_dir();
        dir.push(format!("union-cluster-load-sync-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&dir);
        dir
    };
    {
        let mut cache = ResultCache::open(&sync_cache).expect("open sync cache");
        let stats = sync_from_peer(&a_addr, &mut cache).expect("sync from A");
        assert!(stats.imported >= POOL, "A holds every pool job after failover");
    } // drop flushes the snapshot
    let (server_b2, b2_addr) = bind_server(b_port, Some(sync_cache.clone()));
    assert_eq!(b2_addr, b_addr, "B must restart on its old address");
    let b2_daemon = std::thread::spawn(move || server_b2.run());

    let restart_before = status(&b_addr);
    latencies.clear();
    latencies.extend(run_phase(&owners, seeds, 0xC1A5_0FF5));
    let restart_after = status(&b_addr);
    let restart_lat = Summary::of(&latencies);

    // the restarted member must have answered its partition entirely
    // from the shipped snapshot: zero searches after restart
    let b2_searched = restart_after.num("searched").unwrap_or(f64::NAN)
        - restart_before.num("searched").unwrap_or(f64::NAN);
    let b2_requests = restart_after.num("requests").unwrap_or(f64::NAN)
        - restart_before.num("requests").unwrap_or(f64::NAN);
    assert!(b2_requests > 0.0, "the zipf mix always hits B-owned ranks");
    let restart_warm_hit_rate = 1.0 - b2_searched / b2_requests.max(1.0);

    println!(
        "cluster load: {CLIENTS} clients x zipf(s={ZIPF_EXPONENT}) over {POOL} jobs on 2 peers: \
         {rps:.3e} req/s, p50 {:.3} ms, p95 {:.3} ms; {failovers} failovers; \
         restart warm hit rate {restart_warm_hit_rate:.3} (p95 after restart {:.3} ms)",
        lat.median * 1e3,
        lat.p95 * 1e3,
        restart_lat.p95 * 1e3,
    );

    // deterministic gates
    b.gated_metric("cluster_restart_warm_hit_rate", restart_warm_hit_rate);
    b.gated_metric("cluster_mapping_bit_identical", if bit_identical { 1.0 } else { 0.0 });
    b.gated_metric(
        "cluster_routing_deterministic",
        if routing_deterministic { 1.0 } else { 0.0 },
    );
    b.metric("cluster_load_p50_ms", lat.median * 1e3);
    b.metric("cluster_load_p95_ms", lat.p95 * 1e3);
    b.metric("cluster_load_peers", 2.0);
    b.metric("cluster_load_pool_jobs", POOL as f64);
    b.metric("cluster_load_failovers", failovers as f64);

    shutdown(&b_addr);
    b2_daemon.join().expect("server B2 thread").expect("server B2 exits cleanly");
    shutdown(&a_addr);
    a_daemon.join().expect("server A thread").expect("server A exits cleanly");
    let _ = std::fs::remove_file(&sync_cache);

    b.write_json_env("cluster_load");
}
