//! Bench: regenerate paper Fig. 8 (TC native vs TTGT EDP on the cloud
//! accelerator) and Fig. 9 (optimal intensli2 mappings), timing the
//! drivers.

use union::experiments::{fig8_algorithm_exploration, fig9_mappings, Effort};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 5);
    let (table, points) =
        b.bench("fig08_algorithm_exploration(fast)", || fig8_algorithm_exploration(Effort::Fast));
    print!("{}", table.render());

    // paper shape: TTGT wins every TDS=16 case
    for p in points.iter().filter(|p| p.tds == 16) {
        assert!(
            p.ttgt_edp < p.native_edp,
            "paper shape violated: {} TDS=16 native {:.3e} <= ttgt {:.3e}",
            p.problem,
            p.native_edp,
            p.ttgt_edp
        );
    }
    println!("shape check: TTGT wins all TDS=16 cases ✓");

    let fig9 = b.bench("fig09_mappings(fast)", || fig9_mappings(Effort::Fast));
    println!("{fig9}");
}
