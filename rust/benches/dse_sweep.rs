//! Regenerates the hardware design-space exploration sweep (the
//! edge-class grid family × full ResNet-50) and reports the Pareto
//! frontier plus the pruning and session-reuse statistics. The
//! acceptance checks for the DSE path live here: dominance pruning must
//! skip at least 25% of the arch-point evaluation decisions, and the
//! frontier must be non-trivial. With `UNION_BENCH_DIR` set, the run is
//! recorded as `BENCH_dse_sweep.json` for the bench-regression gate.

use union::experiments::{dse_sweep, Effort};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 1);
    let mut last = None;
    b.bench_rate("dse_sweep(fast, resnet50, edge-grid)", "cand", || {
        let (_, result) = dse_sweep(Effort::Fast);
        let proposed = result.stats.engine.proposed as u64;
        last = Some(result);
        proposed
    });
    let r = last.expect("bench ran at least once");
    print!("{}", r.points_table().render());
    println!();
    print!("{}", r.frontier_table().render());
    println!("{}", r.summary());

    let s = &r.stats;
    assert!(s.evaluated > 0, "sweep must evaluate something");
    assert!(s.frontier_size >= 1, "frontier must be non-empty");
    assert!(
        s.pruned_rate() >= 0.25,
        "dominance pruning must skip >= 25% of arch-point evaluations, got {:.1}% \
         ({} pruned / {} decisions)",
        100.0 * s.pruned_rate(),
        s.pruned,
        s.evaluated + s.pruned,
    );
    assert!(
        s.warm_seeded_jobs > 0,
        "cross-point session reuse must warm-start later searches"
    );

    b.gated_metric("dse_dominated_skip_rate", s.pruned_rate());
    // warm-seed coverage is gated as a rate over jobs run, not an
    // absolute count: better pruning evaluates fewer points, which
    // lowers the absolute count without any regression
    b.gated_metric(
        "dse_warm_seed_rate",
        s.warm_seeded_jobs as f64 / s.jobs_run.max(1) as f64,
    );
    b.metric("dse_warm_seeded_jobs", s.warm_seeded_jobs as f64);
    b.metric("dse_dominated_skips", s.pruned as f64);
    b.metric("dse_evaluated_points", s.evaluated as f64);
    b.metric("dse_frontier_size", s.frontier_size as f64);
    b.metric("dse_engine_memo_hits", s.engine.memo_hits as f64);
    b.write_json_env("dse_sweep");
}
