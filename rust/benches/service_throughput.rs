//! Load generator for the mapping service: requests/sec through the
//! broker with a **cold** cache (every distinct job searches) vs a
//! **warm** persistent cache (every request answers from the store).
//! With `UNION_BENCH_DIR` set, the run is recorded as
//! `BENCH_service_throughput.json` for the bench-regression gate.
//!
//! The workload is a fixed mix: `DISTINCT` small GEMM jobs, each
//! requested `REPEAT` times. Submissions happen against a *paused*
//! broker so the repeat requests deterministically coalesce onto the
//! first submission of their signature (the coalesce count is a gated
//! metric — it is a correctness property, not a timing), then the
//! workers are released and all waiters complete.

use union::arch::presets;
use union::frontend::Workload;
use union::mappers::Objective;
use union::mapspace::Constraints;
use union::service::{Broker, BrokerConfig, CostKind, JobRequest, ResultCache, Submitted};
use union::util::bench::Bencher;

const DISTINCT: usize = 6;
const REPEAT: usize = 4;
const SAMPLES: usize = 80;

fn job(i: usize) -> JobRequest {
    // distinct shapes, all tiny: the bench measures service overheads
    // and cache behavior, not raw search time
    let dims = [16, 24, 32, 40, 48, 64];
    let m = dims[i % dims.len()];
    JobRequest {
        workload: Workload::gemm(&format!("svc-{i}"), m, 16, 16),
        arch: presets::edge(),
        cost: CostKind::Analytical,
        objective: Objective::Edp,
        constraints: Constraints::default(),
        samples: SAMPLES,
        seed: 42,
    }
}

/// Submit the full request mix (paused), release the workers, wait for
/// every answer. Returns requests served.
fn drive(broker: &Broker) -> u64 {
    let mut pending = Vec::new();
    for rep in 0..REPEAT {
        for i in 0..DISTINCT {
            match broker.submit(job(i)) {
                Submitted::Pending { rx, .. } => pending.push(rx),
                Submitted::Cached(_) => {}
                other => {
                    let k = match other {
                        Submitted::Overloaded { .. } => "overloaded",
                        Submitted::Draining => "draining",
                        Submitted::Rejected(_) => "rejected",
                        _ => unreachable!(),
                    };
                    panic!("unexpected submit outcome {k} (rep {rep})");
                }
            }
        }
    }
    broker.resume();
    for rx in pending {
        rx.recv().expect("job answered").result.expect("job succeeded");
    }
    (DISTINCT * REPEAT) as u64
}

fn config() -> BrokerConfig {
    BrokerConfig {
        shards: 2,
        queue_capacity: DISTINCT * REPEAT,
        job_threads: Some(1),
        paused: true,
        // transfer-guided warm starts would let the distinct GEMM
        // shapes seed each other, changing per-job search work between
        // runs of this bench; keep it measuring pure service overheads
        // (the transfer path has its own gated bench, transfer_warm)
        transfer: false,
    }
}

fn main() {
    let mut b = Bencher::with_iters(1, 5);

    // ---- cold: fresh broker + empty cache every iteration ----
    let mut cold_stats = None;
    let cold_rps = b.bench_rate("service_cold_requests", "req", || {
        let broker = Broker::new(config());
        let served = drive(&broker);
        cold_stats = Some(broker.drain());
        served
    });
    let cold = cold_stats.expect("cold bench ran");
    assert_eq!(cold.searched, DISTINCT, "one search per distinct signature");
    assert_eq!(
        cold.coalesced,
        DISTINCT * (REPEAT - 1),
        "paused submission makes every repeat coalesce"
    );

    // ---- warm: one persistent cache file populated once, then every
    // request in every timed iteration is a cache hit ----
    let path = std::env::temp_dir().join(format!(
        "union-bench-service-cache-{}.jsonl",
        std::process::id()
    ));
    std::fs::remove_file(&path).ok();
    {
        let broker = Broker::with_cache(config(), ResultCache::open(&path).unwrap());
        drive(&broker);
        broker.drain();
    }
    let mut warm_stats = None;
    let warm_rps = b.bench_rate("service_warm_cache_requests", "req", || {
        // reopen the store each iteration: the measured path includes
        // loading the cache from disk, as a restarted daemon would
        let broker = Broker::with_cache(config(), ResultCache::open(&path).unwrap());
        let served = drive(&broker);
        warm_stats = Some(broker.drain());
        served
    });
    let warm = warm_stats.expect("warm bench ran");
    assert_eq!(warm.searched, 0, "warm cache serves every request");
    assert_eq!(warm.cache_hits, DISTINCT * REPEAT);
    std::fs::remove_file(&path).ok();

    println!(
        "service throughput: cold {:.3e} req/s, warm {:.3e} req/s ({:.1}x)",
        cold_rps,
        warm_rps,
        warm_rps / cold_rps
    );
    // deterministic quality gates: the coalesce/cache behavior above
    b.gated_metric(
        "service_cold_coalesce_rate",
        cold.coalesced as f64 / (DISTINCT * REPEAT) as f64,
    );
    b.gated_metric(
        "service_warm_cache_hit_rate",
        warm.cache_hits as f64 / (DISTINCT * REPEAT) as f64,
    );
    // timing gate: a warm cache must beat re-searching by a wide margin
    b.gated_metric("service_warm_speedup_vs_cold", warm_rps / cold_rps);
    b.metric("service_distinct_jobs", DISTINCT as f64);
    b.write_json_env("service_throughput");
}
