//! Bench: regenerate paper Fig. 10 — EDP vs flexible-accelerator aspect
//! ratio for the Table IV DNN workloads (MAESTRO-style cost model).

use union::experiments::{fig10_aspect_ratio, Effort};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 3);
    let (edge, cloud, series) =
        b.bench("fig10_aspect_ratio(fast)", || fig10_aspect_ratio(Effort::Fast));
    print!("{}", edge.render());
    println!();
    print!("{}", cloud.render());

    // paper shape: the balanced ratio is best-or-tied for most cases
    let mut ok = 0;
    for (name, points) in &series {
        let balanced = if name.starts_with("edge") { "16x16" } else { "32x64" };
        let v = points
            .iter()
            .find(|(l, _)| l == balanced)
            .map(|(_, v)| *v)
            .unwrap_or(f64::INFINITY);
        if v <= 1.25 {
            ok += 1;
        }
    }
    println!(
        "shape check: balanced ratio within 25% of best for {ok}/{} cases",
        series.len()
    );
    assert!(
        ok * 2 > series.len(),
        "paper shape: balanced aspect ratio should win or tie for most workloads"
    );
}
