//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **mapper quality vs budget** — all five mappers at equal evaluation
//!    budget on the same (workload, arch, model) triple;
//! 2. **order-aware vs order-agnostic reuse** — how much the Timeloop-
//!    style order-awareness changes predicted traffic/EDP;
//! 3. **sparsity extension** — EDP vs input density (future-work feature);
//! 4. **memory-target vs cluster-target map space** — Union's abstraction
//!    contribution quantified: best native-TC EDP with and without the
//!    one-dim-per-level restriction (Table II's comparison made concrete).

use union::cost::{
    AnalyticalModel, Density, EnergyTable, ReuseModel, SparseModel, TileAnalysis,
};
use union::frontend;
use union::mappers::{
    DecoupledMapper, ExhaustiveMapper, GeneticMapper, HeuristicMapper, Mapper, RandomMapper,
};
use union::mapspace::{Constraints, MapSpace};
use union::report::Table;
use union::util::bench::Bencher;
use union::util::rng::Rng;

fn main() {
    let mut b = Bencher::with_iters(1, 3);

    // ---- 1. mapper quality at equal budget ----
    let problem = frontend::dlrm_layers().remove(1).problem();
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&problem, &arch, &cons);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let mappers: Vec<(&str, Box<dyn Mapper>)> = vec![
        ("exhaustive(cap)", Box::new(ExhaustiveMapper::new(2_000))),
        ("random", Box::new(RandomMapper::new(2_000, 7))),
        ("decoupled", Box::new(DecoupledMapper::new(500, 120, 7))),
        ("heuristic", Box::new(HeuristicMapper::new(1_000, 60, 7))),
        ("genetic", Box::new(GeneticMapper::new(60, 32, 7))),
    ];
    let mut t1 = Table::new(
        "Ablation 1: mapper quality at ~2000-evaluation budget (DLRM-2, edge)",
        &["mapper", "best EDP (J*s)", "evaluated", "util"],
    );
    let mut best_edp = f64::INFINITY;
    for (name, mapper) in &mappers {
        let r = b.bench(&format!("mapper_{name}"), || {
            mapper.search(&space, &model).expect("search")
        });
        best_edp = best_edp.min(r.score);
        t1.row(vec![
            name.to_string(),
            format!("{:.3e}", r.score),
            r.evaluated.to_string(),
            format!("{:.2}", r.cost.utilization),
        ]);
    }
    print!("{}", t1.render());

    // ---- 2. order-aware vs order-agnostic reuse ----
    let mut rng = Rng::new(3);
    let mut aware_total = 0.0;
    let mut agnostic_total = 0.0;
    let mut n = 0;
    while n < 200 {
        let Some(m) = space.sample_legal(&mut rng, 100) else { continue };
        let mut ta = TileAnalysis::new(&problem, &arch, &m);
        let aware = ta.movement(ReuseModel::OrderAware);
        let agnostic = ta.movement(ReuseModel::OrderAgnostic);
        aware_total += aware.levels[0].reads;
        agnostic_total += agnostic.levels[0].reads;
        n += 1;
    }
    println!(
        "\nAblation 2: order-aware DRAM reads / order-agnostic = {:.2}x over {n} random \
         mappings\n(loop order matters: data-centric models undercount refetch for \
         order-hostile mappings)\n",
        aware_total / agnostic_total
    );
    assert!(aware_total >= agnostic_total);

    // ---- 3. sparsity-aware extension ----
    let mut t3 = Table::new(
        "Ablation 3: sparsity-aware cost model (future-work extension), DLRM-2 on edge",
        &["input density", "best EDP (J*s)", "eff. MACs"],
    );
    for density in [1.0, 0.5, 0.25, 0.1] {
        let sparse = SparseModel::new(
            AnalyticalModel::new(EnergyTable::default_8bit()),
            Density::uniform(&problem, density),
        );
        let r = RandomMapper::new(800, 11).search(&space, &sparse).expect("sparse search");
        t3.row(vec![
            format!("{density}"),
            format!("{:.3e}", r.score),
            format!("{:.3e}", r.cost.macs as f64),
        ]);
    }
    print!("{}", t3.render());

    // ---- 4. cluster-target vs memory-target map space ----
    let tc = frontend::tccg_problem(&frontend::TCCG[0], 16).problem();
    let cloud = union::arch::presets::cloud(32, 64);
    let free_space = MapSpace::new(&tc, &cloud, &cons);
    let mt_cons = Constraints::memory_target_style();
    let mt_space = MapSpace::new(&tc, &cloud, &mt_cons);
    let free = RandomMapper::new(4_000, 13).search(&free_space, &model);
    let restricted = RandomMapper::new(4_000, 13).search(&mt_space, &model);
    if let (Some(f), Some(r)) = (free, restricted) {
        println!(
            "\nAblation 4: intensli2(TDS=16) native on cloud 32x64\n\
             cluster-target (Union) best EDP:  {:.3e} (util {:.2})\n\
             memory-target (Timeloop) best EDP: {:.3e} (util {:.2})\n\
             Union's concurrent spatial_for semantics recover {:.1}x EDP\n",
            f.score,
            f.cost.utilization,
            r.score,
            r.cost.utilization,
            r.score / f.score
        );
        assert!(
            f.score <= r.score * 1.05,
            "the larger cluster-target space must not lose to its subset"
        );
    }
}
