//! Concurrent-load generator for the mapping service: a zipfian
//! request mix from `CLIENTS` client threads against a **live** TCP
//! server (the bounded reactor), measuring sustained requests/sec and
//! per-request p50/p95 latency over the wire. With `UNION_BENCH_DIR`
//! set, the run is recorded as `BENCH_service_load.json` for the
//! bench-regression gate.
//!
//! Where `service_throughput` drives the broker directly (no sockets),
//! this bench pays the full serving cost: TCP connect, JSON-lines
//! framing, the reactor's poll loop, and the tiered cache. The pool of
//! distinct jobs is warmed first, so the timed phases measure the
//! steady state a long-running daemon converges to: every request a
//! warm-tier hit. Deterministic gates pin the properties that must not
//! rot: the warm-tier hit rate is exactly 1.0, the reactor spawns zero
//! per-connection threads, and the served mapping is bit-identical to
//! a direct `NetworkOrchestrator` run of the same job.

use std::time::Instant;

use union::mappers::Objective;
use union::service::{client_request, JobSpec, Json, Request, ServeConfig, Server};
use union::util::bench::Bencher;
use union::util::stats::Summary;
use union::util::Rng;

/// Distinct jobs in the pool (zipf ranks).
const POOL: usize = 8;
/// Concurrent client threads (the ISSUE floor is K >= 4).
const CLIENTS: usize = 4;
/// Requests each client issues per timed iteration.
const REQS_PER_CLIENT: usize = 40;
/// Search samples per job — tiny on purpose: the bench measures
/// serving overheads, not search time.
const SAMPLES: usize = 60;
/// Zipf exponent: rank r is drawn with weight 1/r^s.
const ZIPF_EXPONENT: f64 = 1.1;

fn spec(i: usize) -> JobSpec {
    let dims = [16, 24, 32, 40, 48, 64, 80, 96];
    JobSpec {
        workload: format!("gemm:{}x16x16", dims[i % dims.len()]),
        arch: "edge".into(),
        cost: "analytical".into(),
        objective: Objective::Edp,
        samples: SAMPLES,
        seed: 42,
        constraints: String::new(),
    }
}

fn request(i: usize) -> Request {
    Request::Search { id: None, spec: spec(i), progress: false }
}

/// Cumulative zipf distribution over the pool ranks.
fn zipf_cumulative() -> [f64; POOL] {
    let mut w = [0.0; POOL];
    let mut total = 0.0;
    for (r, slot) in w.iter_mut().enumerate() {
        *slot = 1.0 / ((r + 1) as f64).powf(ZIPF_EXPONENT);
        total += *slot;
    }
    let mut acc = 0.0;
    for slot in w.iter_mut() {
        acc += *slot / total;
        *slot = acc;
    }
    w[POOL - 1] = 1.0;
    w
}

fn pick(rng: &mut Rng, cum: &[f64; POOL]) -> usize {
    let u = rng.f64();
    cum.iter().position(|&c| u < c).unwrap_or(POOL - 1)
}

/// One load phase: `CLIENTS` threads, each issuing `REQS_PER_CLIENT`
/// zipf-distributed requests over its own connections. Returns every
/// per-request latency in seconds.
fn run_phase(addr: &str, phase_seed: u64) -> Vec<f64> {
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut rng =
                    Rng::new(phase_seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(c as u64 + 1));
                let cum = zipf_cumulative();
                let mut lat = Vec::with_capacity(REQS_PER_CLIENT);
                for _ in 0..REQS_PER_CLIENT {
                    let i = pick(&mut rng, &cum);
                    let t0 = Instant::now();
                    let resp = client_request(&addr, &request(i)).expect("request served");
                    lat.push(t0.elapsed().as_secs_f64());
                    assert_eq!(
                        resp.str("type"),
                        Some("result"),
                        "unexpected response under load: {}",
                        resp.to_line()
                    );
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::with_capacity(CLIENTS * REQS_PER_CLIENT);
    for h in handles {
        all.extend(h.join().expect("client thread"));
    }
    all
}

fn status(addr: &str) -> Json {
    client_request(addr, &Request::Status { id: None }).expect("status served")
}

fn main() {
    let server = Server::bind(ServeConfig { port: 0, ..ServeConfig::default() })
        .expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let stats = server.stats_handle();
    let daemon = std::thread::spawn(move || server.run());

    // warm the pool: one sequential search per distinct job, so the
    // timed phases measure the daemon's steady state
    for i in 0..POOL {
        let r = client_request(&addr, &request(i)).expect("warmup served");
        assert_eq!(r.str("type"), Some("result"), "{}", r.to_line());
    }

    // served answers must be byte-identical to a direct orchestrator
    // run of the same job (checked before the timed window so the
    // extra hit does not skew the hit-rate accounting)
    let served = client_request(&addr, &request(0)).expect("identity probe served");
    let mapping =
        union::service::mapping_from_json(served.get("mapping").expect("mapping present"))
            .expect("mapping decodes");
    let job = union::service::resolve_spec(&spec(0)).expect("spec resolves");
    let direct = {
        use union::network::{NetworkOrchestrator, OrchestratorConfig, WorkloadGraph};
        let graph = WorkloadGraph::from_workloads("direct", vec![job.workload.clone()]);
        let orch = NetworkOrchestrator::with_config(
            &job.arch,
            job.cost.model(),
            &job.constraints,
            OrchestratorConfig {
                objective: job.objective,
                samples: job.samples,
                seed: job.seed,
                threads: Some(1),
            },
        );
        orch.run(&graph).expect("direct run")
    };
    let direct_best = &direct.layers[0].result;
    assert_eq!(mapping, direct_best.mapping, "served mapping differs from direct run");
    assert_eq!(
        served.num("score").expect("score").to_bits(),
        direct_best.score.to_bits(),
        "served score is not bit-identical to the direct run"
    );

    let before = status(&addr);
    let warm_before = before.num("cache_warm_hits").unwrap_or(0.0);

    let mut b = Bencher::with_iters(1, 3);
    let mut latencies: Vec<f64> = Vec::new();
    let mut phase = 0u64;
    let rps = b.bench_rate("service_load_requests", "req", || {
        phase += 1;
        latencies.extend(run_phase(&addr, 0xBEE5 + phase));
        (CLIENTS * REQS_PER_CLIENT) as u64
    });

    let after = status(&addr);
    let warm_after = after.num("cache_warm_hits").unwrap_or(0.0);
    let timed_requests = latencies.len() as f64;
    let warm_hit_rate = (warm_after - warm_before) / timed_requests;

    let lat = Summary::of(&latencies);
    println!(
        "service load: {CLIENTS} clients x zipf(s={ZIPF_EXPONENT}) over {POOL} jobs: \
         {rps:.3e} req/s, p50 {:.3} ms, p95 {:.3} ms, warm hit rate {warm_hit_rate:.3}",
        lat.median * 1e3,
        lat.p95 * 1e3,
    );

    // deterministic gates: steady state is all warm-tier hits, the
    // reactor never spawns a per-connection thread, and the identity
    // check above held
    b.gated_metric("service_load_warm_hit_rate", warm_hit_rate);
    b.gated_metric(
        "service_load_reactor_singlethread",
        if stats.conn_threads_spawned() == 0 { 1.0 } else { 0.0 },
    );
    b.gated_metric("service_load_mapping_bit_identical", 1.0);
    // latency gate, in the harness's higher-is-better convention
    b.gated_metric("service_load_inv_p95_latency", 1.0 / lat.p95.max(1e-9));
    b.metric("service_load_p50_ms", lat.median * 1e3);
    b.metric("service_load_p95_ms", lat.p95 * 1e3);
    b.metric("service_load_clients", CLIENTS as f64);
    b.metric("service_load_pool_jobs", POOL as f64);
    // full latency distribution (µs, log₂ buckets) — recorded for the
    // trajectory; the regression checker validates shape, never gates
    let lat_us: Vec<u64> = latencies.iter().map(|&s| (s * 1e6) as u64).collect();
    b.histogram("service_latency", &lat_us);

    let bye = client_request(&addr, &Request::Shutdown { id: None }).expect("shutdown served");
    assert_eq!(bye.bool_field("ok"), Some(true));
    daemon.join().expect("server thread").expect("server exits cleanly");

    b.write_json_env("service_load");
}
