//! Sparsity scenario bench: drives the density-parameterized
//! sparse-analytical cost kind through the packed search engine and
//! regenerates the density-sweep case study. Reports the sparse search
//! rate against the dense baseline on the same workload (the wrapper
//! adds only a scalar rescale on top of the base model's lean path, so
//! the two rates should be close), plus deterministic quality and
//! coverage metrics from a fixed-budget sweep. With `UNION_BENCH_DIR`
//! set, the run is recorded as `BENCH_sparse_sweep.json` for the
//! bench-regression gate.

use union::arch::presets;
use union::cost::CostKind;
use union::engine::Session;
use union::experiments::{run_case_study, sparsity_sweep, Effort, SPARSITY_DENSITIES};
use union::frontend;
use union::mappers::{portfolio_sources, Objective};
use union::mapspace::{Constraints, MapSpace};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(2, 10);

    // -- search rate: dense vs sparse on the same SpMM problem --------
    let workload = frontend::spmm_workloads().remove(0); // SpMM-1
    let problem = workload.problem();
    let arch = presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&problem, &arch, &cons);
    const SAMPLES: usize = 800;

    let dense = CostKind::Analytical.model();
    let sparse = CostKind::sparse_analytical(0.1, 0.05).unwrap().model();

    let dense_rate = b.bench_rate("sparse_bench_dense_search", "cand", || {
        let mut session = Session::new(dense, Objective::Edp);
        let (result, stats) = session.run_job(&space, &mut portfolio_sources(SAMPLES, 7));
        assert!(result.is_some(), "dense search found no mapping");
        stats.proposed as u64
    });
    let sparse_rate = b.bench_rate("sparse_bench_sparse_search", "cand", || {
        let mut session = Session::new(sparse, Objective::Edp);
        let (result, stats) = session.run_job(&space, &mut portfolio_sources(SAMPLES, 7));
        assert!(result.is_some(), "sparse search found no mapping");
        stats.proposed as u64
    });

    // the sparse hot path must stay engine-grade: pruning and
    // memoization on, allocation-free steady state (tests/alloc_hotpath
    // proves the latter; here we gate the resulting throughput ratio)
    let ratio = sparse_rate / dense_rate.max(1e-9);
    println!("sparse/dense search rate ratio: {ratio:.3}");
    b.gated_metric("sparse_dense_search_rate_ratio", ratio);

    // -- deterministic sweep quality (fixed budget, env-independent) --
    // one fixed-budget search per density on SpMM-1: EDP must improve
    // monotonically as density drops (the whole point of the scenario),
    // and the d=0.1 run must keep the engine's accelerations engaged
    let mut edps = Vec::new();
    let mut last_stats = None;
    for &d in &SPARSITY_DENSITIES {
        let kind = CostKind::sparse_analytical(d, 0.05).unwrap();
        let mut session = Session::new(kind.model(), Objective::Edp);
        let (result, stats) = session.run_job(&space, &mut portfolio_sources(1_000, 13));
        let best = result.expect("sweep search found a mapping");
        println!("d={d}: best EDP {:.3e} (evals {})", best.score, stats.cost_evals);
        edps.push(best.score);
        last_stats = Some(stats);
    }
    // search incumbents are not *pointwise* monotone (each density
    // searches its own trajectory), but the density effect dwarfs
    // search noise; allow a 5% slack
    assert!(
        edps.windows(2).all(|w| w[1] <= w[0] * 1.05),
        "EDP must improve (or hold) as density drops: {edps:?}"
    );
    let stats = last_stats.expect("sweep ran");
    assert!(stats.cost_evals > 0, "sparse sweep must evaluate candidates");
    let edp_gain = edps[0] / edps[edps.len() - 1].max(f64::MIN_POSITIVE);
    b.gated_metric("sparse_sweep_edp_gain_d1_to_d01", edp_gain);
    b.metric("sparse_sweep_memo_hits", stats.memo_hits as f64);
    b.metric("sparse_sweep_pruned", stats.pruned as f64);

    // -- the registered case study end to end (once, untimed: the
    // per-candidate costs above already carry the timing story) -------
    let (per_density, pruned_table) = sparsity_sweep(Effort::Fast);
    assert_eq!(per_density.len(), SPARSITY_DENSITIES.len());
    for (_, table) in &per_density {
        print!("{}", table.render());
        println!();
    }
    print!("{}", pruned_table.render());
    b.metric(
        "sparse_casestudy_rows",
        per_density.iter().map(|(_, t)| t.rows.len()).sum::<usize>() as f64,
    );
    // the CLI dispatch path stays wired (registry-driven, same as
    // kick-tires exercises)
    assert!(
        run_case_study("sparsity", Effort::Custom(20)).is_some(),
        "sparsity case study must be registered"
    );

    b.write_json_env("sparse_sweep");
}
