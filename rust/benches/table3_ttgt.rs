//! Bench: regenerate paper Table III (TC problems + TTGT GEMM dims) and
//! time the frontend transform pipeline (equation parse → plan).

use union::frontend::{tc_workloads, ttgt_gemm};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(2, 10);
    let table = b.bench("table3_ttgt_dims", union::experiments::table3_ttgt_dims);
    print!("{}", table.render());

    // exact values from the paper
    let expect = [
        ("intensli2", 16, (4096u64, 16u64, 16u64)),
        ("intensli2", 64, (262144, 64, 64)),
        ("ccsd7", 16, (256, 16, 256)),
        ("ccsd7", 64, (4096, 64, 4096)),
        ("ccsd-t4", 16, (4096, 4096, 16)),
        ("ccsd-t4", 32, (32768, 32768, 32)),
    ];
    let all = tc_workloads();
    for (name, tds, dims) in expect {
        let (_, _, w) = all
            .iter()
            .find(|(s, t, _)| s.name == name && *t == tds)
            .expect("workload present");
        let plan = ttgt_gemm(w).unwrap();
        assert_eq!((plan.m, plan.n, plan.k), dims, "{name} TDS={tds}");
    }
    println!("Table III exact-match check ✓ (6/6 rows)");

    // throughput of the transform itself (frontend hot path)
    b.bench_throughput("ttgt_transform_throughput", 6, || {
        tc_workloads()
            .iter()
            .map(|(_, _, w)| ttgt_gemm(w).unwrap().m)
            .sum::<u64>()
    });
}
