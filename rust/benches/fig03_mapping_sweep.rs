//! Bench: regenerate paper Fig. 3 — normalized energy/latency/EDP across
//! mappings of a DLRM layer on a 16×16 PE array — and time the driver.

use union::experiments::{fig3_mapping_sweep, Effort};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 5);
    let (table, raw) = b.bench("fig03_mapping_sweep(fast)", || fig3_mapping_sweep(Effort::Fast));
    print!("{}", table.render());
    let edps: Vec<f64> = raw.iter().map(|r| r.2).collect();
    let spread = edps.iter().copied().fold(f64::MIN, f64::max)
        / edps.iter().copied().fold(f64::MAX, f64::min);
    println!("EDP spread: {spread:.1}x across {} mappings", raw.len());
    assert!(spread > 2.0, "paper shape: mappings must differ widely in EDP");
}
