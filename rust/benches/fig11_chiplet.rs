//! Bench: regenerate paper Fig. 11 — EDP vs per-chiplet fill bandwidth on
//! the 16-chiplet (4096-PE) Simba-like package.

use union::experiments::{fig11_chiplet_bandwidth, Effort, FIG11_FILL_BW};
use union::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_iters(1, 3);
    let (table, series) =
        b.bench("fig11_chiplet_bandwidth(fast)", || fig11_chiplet_bandwidth(Effort::Fast));
    print!("{}", table.render());

    // paper shape: EDP falls (weakly) with bandwidth, then saturates
    for (name, points) in &series {
        let first = points.first().unwrap().1;
        let last = points.last().unwrap().1;
        assert!(
            last <= first * 1.05,
            "{name}: EDP should not increase with fill bandwidth ({first:.2} -> {last:.2})"
        );
    }
    // and saturation exists: the last two bandwidth steps differ by <10%
    let saturated = series
        .iter()
        .filter(|(_, pts)| {
            let n = pts.len();
            pts[n - 1].1 >= pts[n - 2].1 * 0.90
        })
        .count();
    println!(
        "shape check: EDP monotone-nonincreasing for all; saturated at 32 GB/s for \
         {saturated}/{} workloads (bw sweep: {FIG11_FILL_BW:?})",
        series.len()
    );
    assert!(saturated * 2 > series.len());
}
