//! Perf bench: the L3 hot paths — cost-model evaluation throughput,
//! map-space sampling, legality checking, full search, and (if artifacts
//! are built) PJRT artifact execution. The EXPERIMENTS.md §Perf numbers
//! come from this target.

use union::cost::{AnalyticalModel, CostModel, EnergyTable, MaestroModel};
use union::frontend;
use union::mappers::{Mapper, RandomMapper};
use union::mapspace::{Constraints, MapSpace};
use union::util::bench::Bencher;
use union::util::rng::Rng;

fn main() {
    let mut b = Bencher::with_iters(2, 10);

    // --- cost model evaluation throughput (the innermost search loop) ---
    let problem = frontend::dlrm_layers().remove(0).problem();
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&problem, &arch, &cons);
    let mut rng = Rng::new(1);
    let mappings: Vec<_> = (0..256)
        .filter_map(|_| space.sample_legal(&mut rng, 100))
        .collect();
    assert!(mappings.len() >= 100, "need a mapping corpus");
    let analytical = AnalyticalModel::new(EnergyTable::default_8bit());
    let maestro = MaestroModel::new(EnergyTable::default_8bit());

    b.bench_throughput("analytical_evaluate (gemm, 4-level)", mappings.len() as u64, || {
        mappings
            .iter()
            .map(|m| analytical.evaluate(&problem, &arch, m).unwrap().cycles)
            .sum::<f64>()
    });
    b.bench_throughput("analytical_prechecked (gemm, 4-level)", mappings.len() as u64, || {
        mappings
            .iter()
            .map(|m| analytical.evaluate_prechecked(&problem, &arch, m).unwrap().cycles)
            .sum::<f64>()
    });
    b.bench_throughput("maestro_evaluate (gemm, 3-real-level)", mappings.len() as u64, || {
        mappings
            .iter()
            .map(|m| maestro.evaluate(&problem, &arch, m).unwrap().cycles)
            .sum::<f64>()
    });

    // conv (7 dims) stresses the tile analysis harder
    let conv = frontend::resnet50_layers().remove(1).problem();
    let conv_space = MapSpace::new(&conv, &arch, &cons);
    let mut rng2 = Rng::new(2);
    let conv_maps: Vec<_> = (0..128)
        .filter_map(|_| conv_space.sample_legal(&mut rng2, 200))
        .collect();
    if !conv_maps.is_empty() {
        b.bench_throughput("analytical_evaluate (conv2d, 7 dims)", conv_maps.len() as u64, || {
            conv_maps
                .iter()
                .map(|m| analytical.evaluate(&conv, &arch, m).unwrap().cycles)
                .sum::<f64>()
        });
    }

    // --- sampling + legality ---
    b.bench_throughput("mapspace_sample (gemm)", 1_000, || {
        let mut r = Rng::new(3);
        (0..1_000).map(|_| space.sample(&mut r).pes_used()).sum::<u64>()
    });
    b.bench_throughput("mapping_check (legality rules)", mappings.len() as u64, || {
        mappings
            .iter()
            .filter(|m| m.check(&problem, &arch).is_ok())
            .count()
    });

    // --- end-to-end search (parallel evaluate_batch inside) ---
    b.bench("random_search_2000 (gemm, parallel)", || {
        RandomMapper::new(2_000, 42)
            .search(&space, &analytical)
            .unwrap()
            .score
    });

    // --- frontend lowering pipeline ---
    b.bench_throughput("lower_tosa_to_affine (conv2d)", 1, || {
        frontend::resnet50_layers().remove(1).lower(false).ops.len()
    });

    // --- PJRT artifact execution (requires `make artifacts`) ---
    if union::runtime::artifacts_available() {
        let rt = union::runtime::Runtime::cpu().expect("pjrt");
        let dir = union::runtime::artifacts_dir();
        let gemm = rt.load_artifact(&dir, "gemm_128").expect("artifact");
        let a = union::runtime::random_tensor(128 * 128, 1);
        let bb = union::runtime::random_tensor(128 * 128, 2);
        let flops = 2u64 * 128 * 128 * 128;
        b.bench_throughput("pjrt_gemm_128 (pallas artifact)", flops, || {
            gemm.run_f32(&[(&a, &[128, 128]), (&bb, &[128, 128])])
                .unwrap()
                .output[0]
        });
    } else {
        println!("(artifacts not built; skipping PJRT benches — run `make artifacts`)");
    }
}
