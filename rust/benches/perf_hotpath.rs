//! Perf bench: the L3 hot paths — cost-model evaluation throughput,
//! map-space sampling, legality checking, full search, the batched
//! engine vs the pre-engine candidate-by-candidate loop, and (if
//! artifacts are built) PJRT artifact execution. The EXPERIMENTS.md
//! §Perf numbers come from this target.

use std::collections::HashMap;

use union::cost::{AnalyticalModel, CostModel, EnergyTable, FootprintMemo, MaestroModel};
use union::engine::{Engine, Session};
use union::frontend;
use union::mappers::{portfolio_sources, Mapper, Objective, RandomMapper};
use union::mapping::Mapping;
use union::mapspace::{Constraints, MapSpace};
use union::util::bench::Bencher;
use union::util::rng::Rng;

/// The actual pre-engine search loop, reproduced from the removed
/// `RandomMapper::search_with` + `evaluate_batch`: parallel sampling,
/// then one parallel admits+evaluate pass over every candidate — no
/// memoization, no pruning, no capacity pre-filter. This is the honest
/// baseline for the engine's ≥2x candidates/sec target. Returns
/// (candidates scored, best EDP).
fn preengine_parallel_loop(
    space: &MapSpace,
    model: &dyn CostModel,
    samples: usize,
    seed: u64,
) -> (u64, f64) {
    let mut rng = Rng::new(seed);
    let seeds: Vec<u64> = (0..samples).map(|_| rng.next_u64()).collect();
    let candidates = union::util::par::par_map(seeds, |&s| {
        let mut r = Rng::new(s);
        space.sample(&mut r)
    });
    let scored = union::util::par::par_map(candidates, |m| {
        if !space.admits(m) {
            return None;
        }
        model
            .evaluate_prechecked(space.problem, space.arch, m)
            .ok()
            .map(|e| e.edp())
    });
    let mut best = f64::INFINITY;
    let mut n = 0u64;
    for s in scored.into_iter().flatten() {
        n += 1;
        if s < best {
            best = s;
        }
    }
    (n, best)
}

/// The candidate-by-candidate loop of ISSUE.md's motivation (§III-B):
/// one candidate sampled, checked and evaluated at a time, single
/// thread. Kept as a second reference point for how much of the win is
/// parallel batching vs memo+pruning.
fn sequential_candidate_loop(
    space: &MapSpace,
    model: &dyn CostModel,
    samples: usize,
    seed: u64,
) -> (u64, f64) {
    let mut rng = Rng::new(seed);
    let mut best = f64::INFINITY;
    let mut scored = 0u64;
    for _ in 0..samples {
        let mut r = Rng::new(rng.next_u64());
        let m = space.sample(&mut r);
        if !space.admits(&m) {
            continue;
        }
        if let Ok(est) = model.evaluate_prechecked(space.problem, space.arch, &m) {
            scored += 1;
            let s = est.edp();
            if s < best {
                best = s;
            }
        }
    }
    (scored, best)
}

/// The pre-packed engine hot path, reproduced faithfully with public
/// APIs: every candidate is a heap-allocated `Mapping`, the memo is
/// keyed by cloned `Mapping`s, the rule-3 pre-filter runs through
/// `FootprintMemo::violates_capacity`, pruning uses the same monotone
/// lower bound, and every survivor pays for a full (allocating)
/// `CostEstimate`. Two phases mirror the portfolio: batched random
/// sampling, then a mutation climb from the incumbent. Returns the
/// number of proposals disposed of.
fn legacy_portfolio_loop(
    space: &MapSpace,
    model: &dyn CostModel,
    samples: usize,
    seed: u64,
) -> u64 {
    let mut memo: HashMap<Mapping, Option<f64>> = HashMap::new();
    let mut tiles = FootprintMemo::new();
    let mut best: Option<(Mapping, f64)> = None;
    let mut rng = Rng::new(seed);
    let mut proposed = 0u64;

    // phase 1: batched random sampling (1024-candidate batches)
    let mut remaining = samples;
    while remaining > 0 {
        let take = remaining.min(1024);
        remaining -= take;
        proposed += take as u64;
        let seeds: Vec<u64> = (0..take).map(|_| rng.next_u64()).collect();
        let batch = union::util::par::par_map(seeds, |&s| {
            let mut r = Rng::new(s);
            space.sample(&mut r)
        });
        let mut miss: Vec<Mapping> = Vec::new();
        for m in batch {
            if memo.contains_key(&m) {
                continue;
            }
            if tiles.violates_capacity(space.problem, space.arch, &m) {
                memo.insert(m, None);
                continue;
            }
            miss.push(m);
        }
        let snapshot = best.as_ref().map(|b| b.1);
        let scored = union::util::par::par_map(miss, |m| {
            if !space.admits(m) {
                return (m.clone(), None);
            }
            if let (Some(inc), Some(bound)) =
                (snapshot, model.lower_bound(space.problem, space.arch, m))
            {
                if bound.edp() >= inc {
                    return (m.clone(), None);
                }
            }
            let s = model
                .evaluate_prechecked(space.problem, space.arch, m)
                .ok()
                .map(|e| e.edp());
            (m.clone(), s)
        });
        for (m, s) in scored {
            if let Some(s) = s {
                if best.as_ref().map(|b| s < b.1).unwrap_or(true) {
                    best = Some((m.clone(), s));
                }
            }
            memo.insert(m, s);
        }
    }

    // phase 2: mutation climb from the incumbent, 16 mutants per round
    if let Some((mut base, mut best_score)) = best {
        let rounds = (samples / 2) / 16;
        for _ in 0..rounds {
            proposed += 16;
            for _ in 0..16 {
                let m = space.mutate(&base, &mut rng);
                if memo.contains_key(&m) {
                    continue;
                }
                if !space.admits(&m) {
                    memo.insert(m, None);
                    continue;
                }
                if let Ok(e) = model.evaluate_prechecked(space.problem, space.arch, &m) {
                    let s = e.edp();
                    memo.insert(m.clone(), Some(s));
                    if s < best_score {
                        best_score = s;
                        base = m;
                    }
                }
            }
        }
        std::hint::black_box(best_score);
    }
    proposed
}

fn main() {
    let mut b = Bencher::with_iters(2, 10);

    // --- cost model evaluation throughput (the innermost search loop) ---
    let problem = frontend::dlrm_layers().remove(0).problem();
    let arch = union::arch::presets::edge();
    let cons = Constraints::default();
    let space = MapSpace::new(&problem, &arch, &cons);
    let mut rng = Rng::new(1);
    let mappings: Vec<_> = (0..256)
        .filter_map(|_| space.sample_legal(&mut rng, 100))
        .collect();
    assert!(mappings.len() >= 100, "need a mapping corpus");
    let analytical = AnalyticalModel::new(EnergyTable::default_8bit());
    let maestro = MaestroModel::new(EnergyTable::default_8bit());

    b.bench_throughput("analytical_evaluate (gemm, 4-level)", mappings.len() as u64, || {
        mappings
            .iter()
            .map(|m| analytical.evaluate(&problem, &arch, m).unwrap().cycles)
            .sum::<f64>()
    });
    b.bench_throughput("analytical_prechecked (gemm, 4-level)", mappings.len() as u64, || {
        mappings
            .iter()
            .map(|m| analytical.evaluate_prechecked(&problem, &arch, m).unwrap().cycles)
            .sum::<f64>()
    });
    b.bench_throughput("maestro_evaluate (gemm, 3-real-level)", mappings.len() as u64, || {
        mappings
            .iter()
            .map(|m| maestro.evaluate(&problem, &arch, m).unwrap().cycles)
            .sum::<f64>()
    });

    // conv (7 dims) stresses the tile analysis harder
    let conv = frontend::resnet50_layers().remove(1).problem();
    let conv_space = MapSpace::new(&conv, &arch, &cons);
    let mut rng2 = Rng::new(2);
    let conv_maps: Vec<_> = (0..128)
        .filter_map(|_| conv_space.sample_legal(&mut rng2, 200))
        .collect();
    if !conv_maps.is_empty() {
        b.bench_throughput("analytical_evaluate (conv2d, 7 dims)", conv_maps.len() as u64, || {
            conv_maps
                .iter()
                .map(|m| analytical.evaluate(&conv, &arch, m).unwrap().cycles)
                .sum::<f64>()
        });
    }

    // --- sampling + legality ---
    b.bench_throughput("mapspace_sample (gemm)", 1_000, || {
        let mut r = Rng::new(3);
        (0..1_000).map(|_| space.sample(&mut r).pes_used()).sum::<u64>()
    });
    b.bench_throughput("mapping_check (legality rules)", mappings.len() as u64, || {
        mappings
            .iter()
            .filter(|m| m.check(&problem, &arch).is_ok())
            .count()
    });

    // --- engine vs pre-engine loop on the Fig. 3 workload ---
    // Fig. 3 searches mappings of DLRM-2 on the 16x16 edge accelerator;
    // this is THE hot path of every figure driver. `cand/s` counts
    // candidates that received a search decision: the legacy loop must
    // evaluate each one, the engine resolves most via batching + memo +
    // lower-bound pruning across all cores.
    let fig3_problem = frontend::dlrm_layers().remove(1).problem();
    let fig3_space = MapSpace::new(&fig3_problem, &arch, &cons);
    const SEARCH_SAMPLES: usize = 4_000;

    // every loop is credited with the proposals it disposes of
    let seq_rate = b.bench_rate("fig3_search_seq (candidate-by-candidate)", "cand", || {
        let (scored, best) =
            sequential_candidate_loop(&fig3_space, &analytical, SEARCH_SAMPLES, 42);
        std::hint::black_box((scored, best));
        SEARCH_SAMPLES as u64
    });
    let pre_rate = b.bench_rate("fig3_search_preengine (parallel, no memo/prune)", "cand", || {
        let (scored, best) =
            preengine_parallel_loop(&fig3_space, &analytical, SEARCH_SAMPLES, 42);
        std::hint::black_box((scored, best));
        SEARCH_SAMPLES as u64
    });
    let engine_rate = b.bench_rate("fig3_search_engine (batched+memo+prune)", "cand", || {
        let mut engine = Engine::new(&fig3_space, &analytical, Objective::Edp);
        let r = engine.run(RandomMapper::new(SEARCH_SAMPLES, 42).source().as_mut());
        std::hint::black_box(r.map(|r| r.score));
        engine.stats().proposed as u64
    });
    let vs_pre = if pre_rate > 0.0 { engine_rate / pre_rate } else { 0.0 };
    let vs_seq = if seq_rate > 0.0 { engine_rate / seq_rate } else { 0.0 };
    println!(
        "fig3 candidates-evaluated/sec: engine {engine_rate:.3e} | \
         pre-engine parallel {pre_rate:.3e} | sequential {seq_rate:.3e}"
    );
    println!(
        "fig3 speedup: {vs_pre:.2}x vs pre-engine parallel batch, \
         {vs_seq:.2}x vs candidate-by-candidate loop (target >= 2x)"
    );
    b.metric("fig3_engine_speedup_vs_preengine", vs_pre);
    b.metric("fig3_engine_speedup_vs_sequential", vs_seq);

    // --- end-to-end search (engine inside) ---
    b.bench("random_search_2000 (gemm, engine)", || {
        RandomMapper::new(2_000, 42)
            .search(&space, &analytical)
            .unwrap()
            .score
    });

    // --- GEMM portfolio: packed zero-alloc engine vs the legacy
    // Mapping-path loop ---
    // The tiled-GEMM map spaces of Moon et al. are what the mapper
    // portfolio grinds through in every case study; this case pits the
    // packed hot path (flat codes, interned memo keys, per-worker tile
    // scratch — no per-candidate heap allocation) against the
    // pre-packed pipeline it replaced: per-candidate `Mapping`
    // allocation, clone-keyed HashMap memo, and a full (allocating)
    // `CostEstimate` per evaluation. Same two-phase portfolio shape
    // (random sampling + mutation climb), same proposal budget.
    {
        let gp = union::problem::gemm(64, 64, 64);
        let gspace = MapSpace::new(&gp, &arch, &cons);
        const PORTFOLIO_SAMPLES: usize = 3_000;
        let legacy_rate = b.bench_rate(
            "gemm_portfolio_legacy (Mapping path, per-candidate allocs)",
            "cand",
            || legacy_portfolio_loop(&gspace, &analytical, PORTFOLIO_SAMPLES, 42),
        );
        let packed_rate = b.bench_rate(
            "gemm_portfolio_engine (packed codes + tile scratch)",
            "cand",
            || {
                let mut session = Session::new(&analytical, Objective::Edp);
                let (r, stats) =
                    session.run_job(&gspace, &mut portfolio_sources(PORTFOLIO_SAMPLES, 42));
                std::hint::black_box(r.map(|r| r.score));
                stats.proposed as u64
            },
        );
        let speedup = if legacy_rate > 0.0 { packed_rate / legacy_rate } else { 0.0 };
        println!(
            "gemm portfolio candidates/sec: packed engine {packed_rate:.3e} | \
             legacy Mapping path {legacy_rate:.3e}  -> {speedup:.2}x (target >= 2x)"
        );
        b.gated_metric("gemm_portfolio_speedup_vs_legacy", speedup);
    }

    // --- network path: cross-layer dedup orchestrator on ResNet-50 ---
    // 54 layers collapse to 24 distinct search jobs on one engine
    // session; `cand/s` credits the proposals the session disposed of,
    // and the dedup hit-rate is the layers served without a search.
    {
        use union::network::{NetworkOrchestrator, OrchestratorConfig};
        let graph = frontend::resnet50_full(1);
        let config = OrchestratorConfig { samples: 120, seed: 42, ..OrchestratorConfig::default() };
        let orchestrator = NetworkOrchestrator::with_config(&arch, &analytical, &cons, config);
        let mut last = None;
        let net_rate = b.bench_rate("resnet50_network (dedup orchestrator)", "cand", || {
            let r = orchestrator.run(&graph).expect("ResNet-50 maps on edge");
            let proposed = r.stats.engine.proposed as u64;
            last = Some(r);
            proposed
        });
        let r = last.expect("bench ran at least once");
        println!(
            "resnet50 network path: {} layers -> {} distinct jobs, dedup hit-rate {:.1}% \
             ({:.3e} cand/s; engine memo hits {})",
            r.stats.layers,
            r.stats.distinct_jobs,
            100.0 * r.stats.dedup_hit_rate,
            net_rate,
            r.stats.engine.memo_hits,
        );
        assert!(
            r.stats.distinct_jobs < r.stats.layers as usize,
            "dedup must evaluate fewer jobs than layers"
        );
        b.gated_metric("resnet50_dedup_hit_rate", r.stats.dedup_hit_rate);
        b.metric("resnet50_distinct_jobs", r.stats.distinct_jobs as f64);
    }

    // --- frontend lowering pipeline ---
    b.bench_throughput("lower_tosa_to_affine (conv2d)", 1, || {
        frontend::resnet50_layers().remove(1).lower(false).ops.len()
    });

    // --- PJRT artifact execution (requires `make artifacts` + --features pjrt) ---
    if union::runtime::artifacts_available() && union::runtime::runtime_available() {
        let rt = union::runtime::Runtime::cpu().expect("pjrt");
        let dir = union::runtime::artifacts_dir();
        let gemm = rt.load_artifact(&dir, "gemm_128").expect("artifact");
        let a = union::runtime::random_tensor(128 * 128, 1);
        let bb = union::runtime::random_tensor(128 * 128, 2);
        let flops = 2u64 * 128 * 128 * 128;
        b.bench_throughput("pjrt_gemm_128 (pallas artifact)", flops, || {
            gemm.run_f32(&[(&a, &[128, 128]), (&bb, &[128, 128])])
                .unwrap()
                .output[0]
        });
    } else {
        println!(
            "(artifacts not built or `pjrt` feature off; skipping PJRT benches — \
             run `make artifacts` and build with --features pjrt)"
        );
    }

    b.write_json_env("perf_hotpath");
}
