#!/usr/bin/env bash
# Service smoke test: start `union serve`, drive it with `union client`,
# and verify the serving invariants end to end:
#
#   1. the served best mapping is BYTE-IDENTICAL to the direct CLI
#      answer for the same job (`union network --mappings`);
#   2. a second client run of the same job is answered from the
#      persistent cache (`"cached":true`) with the identical mapping;
#   3. N concurrent clients are all answered by the single-threaded
#      reactor, one of them streaming anytime progress events;
#   4. status reports exactly one search per distinct job;
#   5. shutdown drains gracefully and the server process exits 0;
#   6. a 2-peer cluster answers rendezvous-routed (`--peers`) and
#      router-proxied requests byte-identically to the direct answer,
#      keeps answering after one peer is killed (failover), and ships
#      its cache to a fresh file via `warm --sync-from`;
#   7. transfer-guided warm starts are advisory: a near-duplicate job
#      served with the transfer index scores within 1.02x of the same
#      job on a `--no-transfer` server, the warm server's status counts
#      the lookup/hit, and the `--no-transfer` server's counters stay 0;
#   8. telemetry is live and consistent: `union metrics` re-emits the
#      broker counters status reports, the search-phase and
#      request-timing histograms hold observations, the Prometheus text
#      parses with complete histogram series, and `union trace` replays
#      the run's flight-recorder events in sequence order.
#
# Used by CI's service-smoke job; runnable locally the same way:
#   scripts/service_smoke.sh

set -euo pipefail
cd "$(dirname "$0")/.."

OUT=out/service
mkdir -p "$OUT"

echo "== building (release) =="
cargo build --release --bin union
BIN=target/release/union

PORT=$(python3 -c 'import socket; s=socket.socket(); s.bind(("127.0.0.1",0)); print(s.getsockname()[1]); s.close()')
CACHE="$OUT/cache.jsonl"
rm -f "$CACHE"

echo "== starting union serve on port $PORT =="
"$BIN" serve --port "$PORT" --cache "$CACHE" --shards 2 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true' EXIT

# wait until the daemon answers status (it builds its broker first)
up=0
for _ in $(seq 1 50); do
    if "$BIN" client status --port "$PORT" >/dev/null 2>&1; then
        up=1
        break
    fi
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "ERROR: server exited before accepting connections" >&2
        exit 1
    fi
    sleep 0.2
done
if [[ $up -ne 1 ]]; then
    echo "ERROR: server never became ready" >&2
    exit 1
fi

JOB=(--workload gemm:64x32x32 --arch edge --cost analytical --objective edp --effort 200 --seed 7)

echo "== direct CLI answer for the same job =="
"$BIN" network --model gemm:64x32x32 --arch edge --cost analytical \
    --objective edp --effort 200 --seed 7 --mappings | tee "$OUT/direct.txt"
# the mapping block is the canonical rendering, from its first line on
sed -n '/^target_cluster/,$p' "$OUT/direct.txt" > "$OUT/direct_mapping.txt"
test -s "$OUT/direct_mapping.txt"

echo "== first client run (fresh search) =="
"$BIN" client search "${JOB[@]}" --port "$PORT" --json | tee "$OUT/first.json"
grep -q '"cached":false' "$OUT/first.json"
"$BIN" client search "${JOB[@]}" --port "$PORT" --mapping-only > "$OUT/served_mapping.txt"

echo "== served mapping must be byte-identical to the direct answer =="
cmp "$OUT/direct_mapping.txt" "$OUT/served_mapping.txt"

echo "== second client run must come from the persistent cache =="
"$BIN" client search "${JOB[@]}" --port "$PORT" --json | tee "$OUT/second.json"
grep -q '"cached":true' "$OUT/second.json"
# bit-identical responses: the full JSON lines match except the id-free
# fields that encode provenance; compare score + mapping directly
python3 - "$OUT/first.json" "$OUT/second.json" <<'EOF'
import json, sys
a = json.load(open(sys.argv[1]))
b = json.load(open(sys.argv[2]))
assert a["score"] == b["score"], (a["score"], b["score"])
assert a["mapping"] == b["mapping"], "cached mapping diverged"
assert a["signature"] == b["signature"], "job signature moved between runs"
EOF

echo "== concurrent clients against the reactor =="
# four clients at once: two fresh jobs, one repeat (cache hit), and one
# fresh job streaming anytime progress events on the same connection as
# its final answer — the bounded reactor multiplexes all of them on one
# thread
"$BIN" client search --workload gemm:48x16x16 --arch edge --cost analytical \
    --objective edp --effort 150 --seed 5 --port "$PORT" --json > "$OUT/conc_a.json" &
PID_A=$!
"$BIN" client search --workload gemm:32x48x16 --arch edge --cost analytical \
    --objective edp --effort 150 --seed 5 --port "$PORT" --json > "$OUT/conc_b.json" &
PID_B=$!
"$BIN" client search "${JOB[@]}" --port "$PORT" --json > "$OUT/conc_c.json" &
PID_C=$!
"$BIN" client search --workload gemm:48x24x24 --arch edge --cost analytical \
    --objective edp --effort 400 --seed 9 --port "$PORT" --json --progress \
    > "$OUT/conc_progress.json" &
PID_D=$!
wait "$PID_A" "$PID_B" "$PID_C" "$PID_D"
grep -q '"type":"result"' "$OUT/conc_a.json"
grep -q '"type":"result"' "$OUT/conc_b.json"
grep -q '"cached":true' "$OUT/conc_c.json"
# the streamed client interleaves progress events before its result
grep -q '"type":"progress"' "$OUT/conc_progress.json"
tail -n 1 "$OUT/conc_progress.json" | grep -q '"type":"result"'

echo "== status + graceful shutdown =="
"$BIN" client status --port "$PORT" | tee "$OUT/status.txt"
# one search per distinct job: the original + 3 fresh concurrent ones
grep -q 'searched=4 ' "$OUT/status.txt"
grep -q 'cache_hits=[1-9]' "$OUT/status.txt"

echo "== telemetry: metrics scrape agrees with status =="
"$BIN" metrics --port "$PORT" | tee "$OUT/metrics.txt"
# the unified registry re-emits the broker counters status prints
grep -q 'broker_searched = 4' "$OUT/metrics.txt"
grep -Eq 'broker_cache_hits = [1-9]' "$OUT/metrics.txt"
grep -Eq 'engine_scored = [1-9]' "$OUT/metrics.txt"
# search-phase spans: one observation per executed job, per phase
grep -Eq 'engine_phase_evaluate_us: n=[1-9]' "$OUT/metrics.txt"
grep -Eq 'engine_phase_sample_us: n=[1-9]' "$OUT/metrics.txt"
# reactor request-timing histograms recorded under load
grep -Eq 'service_request_service_us: n=[1-9]' "$OUT/metrics.txt"
grep -Eq 'service_request_wait_us: n=[1-9]' "$OUT/metrics.txt"

echo "== telemetry: Prometheus text parses and is self-consistent =="
"$BIN" metrics --port "$PORT" --prom > "$OUT/metrics.prom"
python3 - "$OUT/metrics.prom" <<'EOF'
import re, sys
text = open(sys.argv[1]).read()
assert text, "empty Prometheus exposition"
typed = set()
samples = {}
for line in text.splitlines():
    if line.startswith("# TYPE "):
        _, _, name, kind = line.split()
        assert kind in ("gauge", "histogram"), line
        typed.add(name)
        continue
    assert not line.startswith("#"), f"unexpected comment: {line}"
    m = re.match(r'^([a-z0-9_]+)(\{le="[^"]+"\})? (\S+)$', line)
    assert m, f"unparseable sample line: {line}"
    samples[m.group(1) + (m.group(2) or "")] = m.group(3)
assert any(n.startswith("union_broker_") for n in typed), typed
# histogram series are complete: +Inf bucket == _count
for name in [n for n in typed if n + "_count" in samples]:
    inf = samples.get(name + '_bucket{le="+Inf"}')
    assert inf == samples[name + "_count"], (name, inf, samples[name + "_count"])
print(f"prometheus text OK: {len(typed)} metric families, {len(samples)} samples")
EOF

echo "== telemetry: flight recorder holds the run's events =="
"$BIN" trace --port "$PORT" | tee "$OUT/trace.txt"
test -s "$OUT/trace.txt"
grep -q 'job_admitted' "$OUT/trace.txt"
grep -q 'cache_hit' "$OUT/trace.txt"
# --json emits one JSONL document per event, newest last
"$BIN" trace --port "$PORT" --json --limit 8 > "$OUT/trace.jsonl"
python3 - "$OUT/trace.jsonl" <<'EOF'
import json, sys
events = [json.loads(l) for l in open(sys.argv[1]) if l.strip()]
assert events, "flight recorder empty after a full smoke run"
seqs = [e["seq"] for e in events]
assert seqs == sorted(seqs), f"events out of order: {seqs}"
assert all(set(e) == {"seq", "t_us", "event", "detail"} for e in events), events[0]
print(f"trace OK: {len(events)} events, latest seq {seqs[-1]}")
EOF

"$BIN" client shutdown --port "$PORT"
wait "$SERVER_PID"
trap - EXIT

# the cache file survives the daemon and holds the one record
test -s "$CACHE"
grep -q 'union_result_cache' "$CACHE"

# ---- multi-process cluster: routing, router, failover, sync ----

free_port() {
    python3 -c 'import socket; s=socket.socket(); s.bind(("127.0.0.1",0)); print(s.getsockname()[1]); s.close()'
}

wait_ready() { # wait_ready <port> <pid>
    local port=$1 pid=$2 i
    for i in $(seq 1 50); do
        if "$BIN" client status --port "$port" >/dev/null 2>&1; then
            return 0
        fi
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "ERROR: process $pid exited before accepting connections" >&2
            return 1
        fi
        sleep 0.2
    done
    echo "ERROR: port $port never became ready" >&2
    return 1
}

echo "== cluster: starting two peers =="
PORT_A=$(free_port)
PORT_B=$(free_port)
CACHE_A="$OUT/cache_a.jsonl"
CACHE_B="$OUT/cache_b.jsonl"
rm -f "$CACHE_A" "$CACHE_B"
"$BIN" serve --port "$PORT_A" --cache "$CACHE_A" --shards 2 &
PID_A=$!
"$BIN" serve --port "$PORT_B" --cache "$CACHE_B" --shards 2 &
PID_B=$!
trap 'kill "$PID_A" "$PID_B" 2>/dev/null || true' EXIT
wait_ready "$PORT_A" "$PID_A"
wait_ready "$PORT_B" "$PID_B"
PEERS="127.0.0.1:$PORT_A,127.0.0.1:$PORT_B"

echo "== routed answer must equal the direct answer =="
"$BIN" client search "${JOB[@]}" --peers "$PEERS" --mapping-only > "$OUT/routed_mapping.txt"
cmp "$OUT/direct_mapping.txt" "$OUT/routed_mapping.txt"

echo "== same answer through the router proxy =="
ROUTER_PORT=$(free_port)
"$BIN" router --peers "$PEERS" --port "$ROUTER_PORT" &
PID_R=$!
wait_ready "$ROUTER_PORT" "$PID_R"
"$BIN" client search "${JOB[@]}" --port "$ROUTER_PORT" --mapping-only > "$OUT/router_mapping.txt"
cmp "$OUT/direct_mapping.txt" "$OUT/router_mapping.txt"
# router shutdown stops only the proxy; both peers keep serving
"$BIN" client shutdown --port "$ROUTER_PORT"
wait "$PID_R"

echo "== failover: kill one peer, the survivor answers byte-identically =="
kill "$PID_B" 2>/dev/null || true
wait "$PID_B" 2>/dev/null || true
"$BIN" client search "${JOB[@]}" --peers "$PEERS" --mapping-only > "$OUT/failover_mapping.txt"
cmp "$OUT/direct_mapping.txt" "$OUT/failover_mapping.txt"

echo "== snapshot sync: warm a fresh cache from the survivor =="
SYNCED="$OUT/cache_synced.jsonl"
rm -f "$SYNCED"
"$BIN" warm --cache "$SYNCED" --sync-from "127.0.0.1:$PORT_A" | tee "$OUT/sync.txt"
grep -q 'imported' "$OUT/sync.txt"
test -s "$SYNCED"
grep -q 'union_result_cache' "$SYNCED"

echo "== broadcast shutdown reaches the survivor despite the dead peer =="
"$BIN" client shutdown --peers "$PEERS" | tee "$OUT/cluster_shutdown.txt"
wait "$PID_A"
trap - EXIT

# ---- transfer-guided warm starts: advisory, counted, switchable ----

# the donor job populates the cache + transfer index; the query is the
# same operator family at a scaled size, so on the warm server it is a
# cache MISS that warm-starts from the donor's winner
DONOR=(--workload gemm:64x24x24 --arch edge --cost analytical --objective edp --effort 200 --seed 7)
QUERY=(--workload gemm:128x24x24 --arch edge --cost analytical --objective edp --effort 200 --seed 7)

echo "== transfer on: donor then near-duplicate query =="
PORT_T=$(free_port)
CACHE_T="$OUT/cache_transfer.jsonl"
rm -f "$CACHE_T"
"$BIN" serve --port "$PORT_T" --cache "$CACHE_T" --shards 2 &
PID_T=$!
trap 'kill "$PID_T" 2>/dev/null || true' EXIT
wait_ready "$PORT_T" "$PID_T"
"$BIN" client search "${DONOR[@]}" --port "$PORT_T" --json > "$OUT/transfer_donor.json"
"$BIN" client search "${QUERY[@]}" --port "$PORT_T" --json | tee "$OUT/transfer_on.json"
grep -q '"cached":false' "$OUT/transfer_on.json"
"$BIN" client status --port "$PORT_T" | tee "$OUT/transfer_status_on.txt"
# the query's enqueue consulted the index and found the donor
grep -Eq 'transfer: index_entries=[1-9]' "$OUT/transfer_status_on.txt"
grep -Eq 'lookups=[1-9]' "$OUT/transfer_status_on.txt"
grep -Eq 'hits=[1-9]' "$OUT/transfer_status_on.txt"
"$BIN" client shutdown --port "$PORT_T"
wait "$PID_T"
trap - EXIT

echo "== transfer off: same jobs on a --no-transfer server, fresh cache =="
PORT_N=$(free_port)
CACHE_N="$OUT/cache_no_transfer.jsonl"
rm -f "$CACHE_N"
"$BIN" serve --port "$PORT_N" --cache "$CACHE_N" --shards 2 --no-transfer &
PID_N=$!
trap 'kill "$PID_N" 2>/dev/null || true' EXIT
wait_ready "$PORT_N" "$PID_N"
"$BIN" client search "${DONOR[@]}" --port "$PORT_N" --json > "$OUT/transfer_donor_off.json"
"$BIN" client search "${QUERY[@]}" --port "$PORT_N" --json | tee "$OUT/transfer_off.json"
"$BIN" client status --port "$PORT_N" | tee "$OUT/transfer_status_off.txt"
grep -q 'transfer: index_entries=0 lookups=0 hits=0 seeded=0 wins=0' "$OUT/transfer_status_off.txt"
"$BIN" client shutdown --port "$PORT_N"
wait "$PID_N"
trap - EXIT

echo "== warm-started answer within the 1.02x quality tolerance =="
# the portfolio's hill-climbing phase reacts to the incumbent, so warm
# answers are pinned to a tolerance, not bit-equality (the strict
# never-worse guarantee on progress-independent streams is the
# transfer_warm bench's gate)
python3 - "$OUT/transfer_on.json" "$OUT/transfer_off.json" <<'EOF'
import json, sys
on = json.load(open(sys.argv[1]))
off = json.load(open(sys.argv[2]))
assert on["signature"] == off["signature"], "the two servers saw different jobs"
assert on["score"] <= off["score"] * 1.02, \
    f"warm-started score {on['score']} worse than 1.02x cold {off['score']}"
EOF

echo "service smoke OK"
