#!/usr/bin/env bash
# Release-build profiling wrapper for the search hot path.
#
# Usage:
#   scripts/profile.sh search [args...]    # profile `union search ...`
#   scripts/profile.sh bench <name>        # profile one bench binary
#   scripts/profile.sh stat <any of the above>
#   scripts/profile.sh telemetry [args...] # live-watch a running server's
#                                          # metrics (no perf involved)
#
# Examples:
#   scripts/profile.sh search --workload gemm:512x512x512 --arch edge
#   scripts/profile.sh bench perf_hotpath
#   scripts/profile.sh stat bench perf_hotpath
#   scripts/profile.sh telemetry --port 7415 --interval-ms 1000
#
# Output goes to out/profile/: a perf.data plus, when a flamegraph tool
# is available (inferno-flamegraph or flamegraph.pl on PATH), an SVG.
# Falls back to `perf stat` summaries, and to plain `/usr/bin/time -v`
# when perf itself is missing — so the script degrades gracefully on
# locked-down runners instead of failing.
set -euo pipefail

cd "$(dirname "$0")/.."
OUT=out/profile
mkdir -p "$OUT"

MODE=record
if [[ "${1:-}" == "stat" ]]; then
    MODE=stat
    shift
fi

if [[ $# -lt 1 ]]; then
    echo "usage: $0 [stat] search [args...] | [stat] bench <name> | telemetry [args...]" >&2
    exit 2
fi

KIND=$1
shift
case "$KIND" in
telemetry)
    # not a perf run: attach to a live `union serve` and re-scrape its
    # telemetry registry (phase histograms, broker/cache counters) on an
    # interval — the sampling-profiler view from the server's own spans
    cargo build --release
    exec target/release/union metrics --watch "$@"
    ;;
search)
    cargo build --release
    CMD=(target/release/union search "$@")
    LABEL=search
    ;;
bench)
    NAME=${1:?bench name required}
    shift || true
    # build the bench binary without running it, then locate it
    cargo bench --bench "$NAME" --no-run
    BIN=$(ls -t target/release/deps/"$NAME"-* 2>/dev/null | grep -v '\.d$' | head -1)
    [[ -n "$BIN" ]] || { echo "bench binary for '$NAME' not found" >&2; exit 1; }
    CMD=("$BIN" "$@")
    LABEL="bench-$NAME"
    ;;
*)
    echo "unknown target '$KIND' (want: search | bench <name>)" >&2
    exit 2
    ;;
esac

if ! command -v perf >/dev/null 2>&1; then
    echo "perf not available; falling back to /usr/bin/time -v" >&2
    /usr/bin/time -v "${CMD[@]}" 2>"$OUT/$LABEL.time.txt" || true
    echo "wrote $OUT/$LABEL.time.txt"
    exit 0
fi

if [[ "$MODE" == stat ]]; then
    perf stat -d -o "$OUT/$LABEL.stat.txt" -- "${CMD[@]}"
    echo "wrote $OUT/$LABEL.stat.txt"
    exit 0
fi

perf record -F 997 -g --call-graph dwarf -o "$OUT/$LABEL.perf.data" -- "${CMD[@]}"
echo "wrote $OUT/$LABEL.perf.data"

# flamegraph, with whichever tool is installed
if command -v inferno-flamegraph >/dev/null 2>&1 && command -v inferno-collapse-perf >/dev/null 2>&1; then
    perf script -i "$OUT/$LABEL.perf.data" | inferno-collapse-perf \
        | inferno-flamegraph >"$OUT/$LABEL.svg"
    echo "wrote $OUT/$LABEL.svg"
elif command -v flamegraph.pl >/dev/null 2>&1 && command -v stackcollapse-perf.pl >/dev/null 2>&1; then
    perf script -i "$OUT/$LABEL.perf.data" | stackcollapse-perf.pl \
        | flamegraph.pl >"$OUT/$LABEL.svg"
    echo "wrote $OUT/$LABEL.svg"
else
    echo "no flamegraph tool on PATH; inspect with: perf report -i $OUT/$LABEL.perf.data"
fi
