#!/usr/bin/env python3
"""Bench-regression gate: compare BENCH_<name>.json runs to baselines.

The benches (``cargo bench --bench perf_hotpath --bench network_sweep
--bench dse_sweep --bench service_throughput`` with ``UNION_BENCH_DIR``
set) write one JSON file each, recording every timing report (with
candidates/sec throughput where applicable) and every named metric
(dedup hit-rate, dominated-skip count, ...). This script fails CI when
the current run regresses against the committed baselines in
bench/baselines/:

* every baseline *throughput* must reach at least (1 - threshold) x its
  baseline value (higher is better);
* every baseline *gated metric* is held to the same rule;
* a baseline entry missing from the current run fails outright —
  coverage cannot silently vanish;
* the reverse direction fails too: a gated entry the current run emits
  with no baseline record, and a whole BENCH_<name>.json with no
  committed baseline, each fail with a message naming the entry and
  pointing at ``--update`` — new coverage must be seeded, not silently
  ungated;
* malformed bench JSON (unparsable file, entry without a name,
  non-numeric value, ill-formed histogram) fails with a clear per-file
  message, never a traceback;
* plain (non-gated) metrics and timing means are recorded for the
  trajectory but never gate;
* latency *histograms* (``"histograms"``, emitted by e.g.
  ``service_load``'s ``service_latency``) are validated for shape —
  name, integer count/sum, ``[bucket_index, count]`` pairs in strictly
  ascending index order — and reported, but never gate: a log2 latency
  distribution is lower-is-better and multi-dimensional, so it does not
  fit the higher-is-better floor rule;
* the three bench registries must agree: every ``--bench X`` in CI's
  bench-regression job needs a committed ``BENCH_X.json`` baseline and
  a ``rust/benches/X.rs`` source, and every committed baseline must be
  in CI's bench list — a bench dropped from any one of the three fails
  with a message naming it (the paper-figure benches — ``ablations``,
  ``fig*``, ``table3_ttgt`` — are artifact generators, deliberately in
  neither CI's gate nor the baselines).

Refresh baselines after a legitimate speedup with ``--update`` (see
bench/README.md). Only stdlib is used; no pip installs.
"""

import argparse
import json
import os
import pathlib
import re
import shutil
import sys


def gated_entries(doc, fname):
    """Extract {key: value} for everything that participates in the gate.

    Malformed entries (no name, non-numeric value) fail with a clear
    message naming the file and entry, never a KeyError traceback.
    """
    out = {}
    for r in doc.get("results", []):
        name = r.get("name")
        if not name:
            raise BenchFileError(f"{fname}: result entry without a 'name': {r!r}")
        tp = r.get("throughput")
        if tp is not None:
            try:
                out["throughput:" + name] = float(tp)
            except (TypeError, ValueError):
                raise BenchFileError(
                    f"{fname}: throughput of '{name}' is not a number: {tp!r}")
    for m in doc.get("metrics", []):
        name = m.get("name")
        if not name:
            raise BenchFileError(f"{fname}: metric entry without a 'name': {m!r}")
        if m.get("gated") and m.get("value") is not None:
            try:
                out["metric:" + name] = float(m["value"])
            except (TypeError, ValueError):
                raise BenchFileError(
                    f"{fname}: gated metric '{name}' is not a number: {m['value']!r}")
    return out


def validate_histograms(doc, fname):
    """Shape-check the optional ``"histograms"`` array; return {name: count}.

    Histograms are recorded for the trajectory (and summarised in the
    run output) but never gate — still, a malformed one is a bench bug
    and must fail loudly like any other malformed entry.
    """
    out = {}
    hists = doc.get("histograms", [])
    if not isinstance(hists, list):
        raise BenchFileError(f"{fname}: 'histograms' is not a list: {hists!r}")
    for h in hists:
        if not isinstance(h, dict):
            raise BenchFileError(f"{fname}: histogram entry is not an object: {h!r}")
        name = h.get("name")
        if not name or not isinstance(name, str):
            raise BenchFileError(f"{fname}: histogram entry without a 'name': {h!r}")
        for field in ("count", "sum"):
            v = h.get(field)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise BenchFileError(
                    f"{fname}: histogram '{name}' field '{field}' is not a "
                    f"non-negative integer: {v!r}")
        buckets = h.get("buckets")
        if not isinstance(buckets, list):
            raise BenchFileError(
                f"{fname}: histogram '{name}' has no 'buckets' list: {buckets!r}")
        prev_idx = -1
        total = 0
        for pair in buckets:
            ok_pair = (isinstance(pair, list) and len(pair) == 2
                       and all(isinstance(x, int) and not isinstance(x, bool)
                               and x >= 0 for x in pair))
            if not ok_pair:
                raise BenchFileError(
                    f"{fname}: histogram '{name}' bucket is not a "
                    f"[index, count] pair of non-negative ints: {pair!r}")
            idx, n = pair
            if idx <= prev_idx:
                raise BenchFileError(
                    f"{fname}: histogram '{name}' bucket indices must be "
                    f"strictly ascending (index {idx} after {prev_idx})")
            prev_idx = idx
            total += n
        if total != h["count"]:
            raise BenchFileError(
                f"{fname}: histogram '{name}' bucket counts sum to {total} "
                f"but 'count' is {h['count']}")
        out[name] = h["count"]
    return out


class BenchFileError(Exception):
    """A bench JSON file that cannot be compared (clear message, no traceback)."""


def load_bench_file(path):
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise BenchFileError(f"{path}: unreadable bench JSON ({e})")
    validate_histograms(doc, path.name)
    return doc


def render_table(rows, markdown=False):
    """Per-metric delta table: (verdict, file, key, current, baseline, delta%)."""
    header = ("verdict", "bench file", "entry", "current", "baseline", "delta")
    body = [
        (verdict, fname, key, f"{cur:.4g}", f"{base:.4g}",
         "n/a" if base == 0 else f"{(cur / base - 1.0) * 100.0:+.1f}%")
        for verdict, fname, key, cur, base in rows
    ]
    if markdown:
        lines = ["| " + " | ".join(header) + " |",
                 "|" + "|".join("---" for _ in header) + "|"]
        lines += ["| " + " | ".join(r) + " |" for r in body]
        return "\n".join(lines)
    widths = [max(len(h), *(len(r[i]) for r in body)) if body else len(h)
              for i, h in enumerate(header)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths))]
    lines += ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in body]
    return "\n".join(lines)


def coverage_failures(baselines):
    """Cross-check the three bench registries against each other.

    Returns failure strings when CI's ``--bench X`` list, the committed
    ``BENCH_X.json`` baselines and the ``rust/benches/X.rs`` sources
    disagree — a gated bench silently dropped from any one of them is
    exactly the hole this guards against. Paper-figure benches live in
    ``rust/benches/`` without baselines or a CI gate entry by design,
    so bench sources are only required to *exist*, never to be gated.
    """
    repo = pathlib.Path(__file__).resolve().parent.parent
    ci_path = repo / ".github" / "workflows" / "ci.yml"
    benches_dir = repo / "rust" / "benches"
    failures = []
    if not ci_path.exists() or not benches_dir.is_dir():
        # running against an exported tree (bench JSON only): nothing
        # to cross-check, and inventing failures would block --update
        return failures
    ci_names = set(re.findall(r"--bench\s+([A-Za-z0-9_]+)", ci_path.read_text()))
    baseline_names = {p.name[len("BENCH_"):-len(".json")]
                      for p in baselines.glob("BENCH_*.json")}
    for name in sorted(ci_names - baseline_names):
        failures.append(
            f"coverage: CI runs --bench {name} but {baselines}/BENCH_{name}.json "
            f"is not committed — the gate would fail it as an unseeded bench; "
            f"seed it with --update and commit the baseline")
    for name in sorted(baseline_names - ci_names):
        failures.append(
            f"coverage: baseline BENCH_{name}.json is committed but ci.yml's "
            f"bench-regression job never runs --bench {name} — the gate would "
            f"fail on the missing current file; add it to the cargo bench line")
    for name in sorted(ci_names | baseline_names):
        if not (benches_dir / f"{name}.rs").exists():
            failures.append(
                f"coverage: bench '{name}' is registered (CI and/or baseline) "
                f"but rust/benches/{name}.rs does not exist")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baselines", default="bench/baselines",
                    help="directory of committed BENCH_<name>.json baselines")
    ap.add_argument("--current", default="out/bench",
                    help="directory of freshly recorded BENCH_<name>.json files")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="allowed fractional drop before failing (default 0.15)")
    ap.add_argument("--update", action="store_true",
                    help="copy current files over the baselines instead of comparing")
    args = ap.parse_args()

    baselines = pathlib.Path(args.baselines)
    current = pathlib.Path(args.current)

    if args.update:
        files = sorted(current.glob("BENCH_*.json"))
        if not files:
            sys.exit(f"no BENCH_*.json files found in {current}")
        # validate everything BEFORE copying anything: a malformed file
        # must refuse the whole update, not leave baselines half-replaced
        for cur in files:
            try:
                load_bench_file(cur)
            except BenchFileError as e:
                sys.exit(f"refusing to update baselines (nothing copied): {e}")
        baselines.mkdir(parents=True, exist_ok=True)
        for cur in files:
            shutil.copy(cur, baselines / cur.name)
            print(f"baseline updated: {baselines / cur.name}")
        return

    baseline_files = sorted(baselines.glob("BENCH_*.json"))
    if not baseline_files:
        sys.exit(f"no baselines in {baselines} — run with --update to create them")

    failures = coverage_failures(baselines)
    rows = []
    hist_report = []  # (file, histogram name, count) — informational only
    for base_path in baseline_files:
        cur_path = current / base_path.name
        if not cur_path.exists():
            failures.append(f"{base_path.name}: current run file missing from {current}")
            continue
        try:
            base = gated_entries(load_bench_file(base_path), base_path.name)
            cur_doc = load_bench_file(cur_path)
            cur = gated_entries(cur_doc, cur_path.name)
            cur_hists = validate_histograms(cur_doc, cur_path.name)
        except BenchFileError as e:
            failures.append(str(e))
            continue
        hist_report += [(cur_path.name, name, n) for name, n in sorted(cur_hists.items())]
        for key, base_val in sorted(base.items()):
            if key not in cur:
                failures.append(f"{base_path.name}: '{key}' missing from current run")
                continue
            cur_val = cur[key]
            floor = base_val * (1.0 - args.threshold)
            verdict = "ok" if cur_val >= floor else "REGRESSION"
            rows.append((verdict, base_path.name, key, cur_val, base_val))
            if cur_val < floor:
                failures.append(
                    f"{base_path.name}: '{key}' regressed to {cur_val:.4g} "
                    f"(baseline {base_val:.4g}, floor {floor:.4g})")
        # a bench that now emits gated entries the baseline does not
        # record is running ungated — fail loudly rather than letting
        # new coverage silently float
        for key in sorted(set(cur) - set(base)):
            failures.append(
                f"{base_path.name}: current run emits '{key}' but the baseline has "
                f"no entry for it — record it with --update (and commit bench/baselines/)")

    # whole bench files that exist in the current run but have no
    # committed baseline at all: new benches that need seeding
    baseline_names = {p.name for p in baseline_files}
    new_benches = [p.name for p in sorted(current.glob("BENCH_*.json"))
                   if p.name not in baseline_names]
    for name in new_benches:
        failures.append(
            f"{name}: new bench with no committed baseline — seed it with --update "
            f"(and commit bench/baselines/{name})")

    print(render_table(rows))
    print(f"\ncompared {len(rows)} gated entries across {len(baseline_files)} bench files")
    for fname, name, n in hist_report:
        print(f"histogram (recorded, not gated): {fname}: '{name}' with {n} observations")

    # when running in GitHub Actions, publish the delta table to the
    # job summary so a reviewer sees per-metric movement, not only the
    # pass/fail bit
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        verdict_line = ("**bench-regression: FAILED**" if failures
                        else "**bench-regression: green**")
        with open(summary_path, "a", encoding="utf-8") as f:
            f.write("## Bench regression deltas\n\n")
            f.write(render_table(rows, markdown=True))
            f.write(f"\n\n{verdict_line} — threshold {args.threshold:.0%}, "
                    f"{len(rows)} gated entries\n")
            for fail in failures:
                f.write(f"- ❌ {fail}\n")
    if failures:
        print("\nbench-regression FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        print("\nIf a slowdown is expected (e.g. the bench now does more work), a "
              "speedup legitimately moved a baseline, or a new bench/metric needs "
              "seeding, refresh with:\n"
              "  UNION_BENCH_DIR=$PWD/out/bench cargo bench --bench perf_hotpath "
              "--bench network_sweep --bench dse_sweep --bench service_throughput "
              "--bench service_load --bench sparse_sweep --bench cluster_load "
              "--bench transfer_warm\n"
              "  python3 scripts/check_bench_regression.py --update\n"
              "and commit bench/baselines/ (see bench/README.md).", file=sys.stderr)
        sys.exit(1)
    print("bench-regression gate: green")


if __name__ == "__main__":
    main()
