#!/usr/bin/env bash
# Regenerate every paper artifact at fast effort into out/.
#
# Used by CI's smoke job and by reviewers: if any figure driver panics
# or produces an empty table, this exits nonzero. `--thorough` forwards
# the high-effort search budget (slow; not for CI).
#
#   scripts/kick_tires.sh [--thorough]

set -euo pipefail
cd "$(dirname "$0")/.."

EFFORT_FLAG=""
if [[ "${1:-}" == "--thorough" ]]; then
    EFFORT_FLAG="--thorough"
fi

OUT=out
mkdir -p "$OUT"

echo "== building (release) =="
cargo build --release --bin union

BIN=target/release/union
ARTIFACTS=(fig3 fig8 fig9 fig10 fig11 table3)

for fig in "${ARTIFACTS[@]}"; do
    echo "== $fig =="
    # shellcheck disable=SC2086  # EFFORT_FLAG is intentionally word-split
    "$BIN" casestudy "$fig" $EFFORT_FLAG | tee "$OUT/$fig.txt"
done

echo "== checking outputs =="
status=0
for fig in "${ARTIFACTS[@]}"; do
    if [[ ! -s "$OUT/$fig.txt" ]]; then
        echo "ERROR: $OUT/$fig.txt is empty" >&2
        status=1
    fi
done

if [[ $status -eq 0 ]]; then
    echo "kick-tires OK: ${#ARTIFACTS[@]} artifacts regenerated in $OUT/"
fi
exit $status
