#!/usr/bin/env bash
# Regenerate every paper artifact at fast effort into out/.
#
# Used by CI's smoke job and by reviewers: if any case-study driver
# panics or produces an empty table, this exits nonzero. The artifact
# list is NOT hard-coded here: it comes from `union casestudy --list`,
# which prints the CASE_STUDIES registry in rust/src/experiments/mod.rs
# — so adding a case study there automatically adds CI coverage, and a
# registry/CI drift is impossible by construction. `--thorough`
# forwards the high-effort search budget (slow; not for CI).
#
#   scripts/kick_tires.sh [--thorough]

set -euo pipefail
cd "$(dirname "$0")/.."

EFFORT_FLAG=""
if [[ "${1:-}" == "--thorough" ]]; then
    EFFORT_FLAG="--thorough"
fi

OUT=out
mkdir -p "$OUT"

echo "== building (release) =="
cargo build --release --bin union

BIN=target/release/union

# portable read loop (mapfile needs bash 4; macOS ships 3.2)
ARTIFACTS=()
while IFS= read -r id; do
    [[ -n "$id" ]] && ARTIFACTS+=("$id")
done < <("$BIN" casestudy --list)
# guard the real failure mode (empty/garbage output) without
# duplicating the registry size here
if [[ ${#ARTIFACTS[@]} -lt 1 ]]; then
    echo "ERROR: casestudy --list returned no ids" >&2
    exit 1
fi
echo "== registry: ${ARTIFACTS[*]} =="

for fig in "${ARTIFACTS[@]}"; do
    echo "== $fig =="
    # shellcheck disable=SC2086  # EFFORT_FLAG is intentionally word-split
    "$BIN" casestudy "$fig" $EFFORT_FLAG | tee "$OUT/$fig.txt"
done

# network-level co-design: ResNet-50 end to end on the edge preset. At
# fast effort the orchestrator's cross-layer dedup (54 layers -> ~24
# distinct search jobs) keeps this CI-cheap.
echo "== network_resnet50 =="
# shellcheck disable=SC2086
"$BIN" network --model resnet50 --arch edge $EFFORT_FLAG | tee "$OUT/network_resnet50.txt"

CHECK_FILES=("${ARTIFACTS[@]}" network_resnet50)

echo "== checking outputs =="
status=0
for fig in "${CHECK_FILES[@]}"; do
    if [[ ! -s "$OUT/$fig.txt" ]]; then
        echo "ERROR: $OUT/$fig.txt is empty" >&2
        status=1
    fi
done
if ! grep -q "distinct search jobs" "$OUT/network_resnet50.txt"; then
    echo "ERROR: network run did not report its dedup summary" >&2
    status=1
fi
if ! grep -q "skipped by dominance pruning" "$OUT/dse.txt"; then
    echo "ERROR: dse run did not report its pruning summary" >&2
    status=1
fi

if [[ $status -eq 0 ]]; then
    echo "kick-tires OK: ${#CHECK_FILES[@]} artifacts regenerated in $OUT/"
fi
exit $status
