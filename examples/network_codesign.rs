//! Network-level co-design walkthrough: map the full ResNet-50 end to
//! end on the edge and cloud accelerators, letting the orchestrator
//! dedup the 54 layers into ~24 distinct search jobs on one engine
//! session, then compare the end-to-end rollups.
//!
//! ```sh
//! cargo run --release --example network_codesign [-- --thorough]
//! ```

use union::cost::{AnalyticalModel, EnergyTable};
use union::experiments::Effort;
use union::network::{NetworkOrchestrator, OrchestratorConfig};
use union::prelude::*;

fn main() {
    let effort = if std::env::args().any(|a| a == "--thorough") {
        Effort::Thorough
    } else {
        Effort::Fast
    };
    let graph = frontend::resnet50_full(1);
    println!(
        "network {}: {} layers in {} repeat-compressed nodes, {:.3e} MACs\n",
        graph.name,
        graph.total_layers(),
        graph.len(),
        graph.total_macs() as f64
    );

    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let constraints = Constraints::default();
    for (label, arch) in [
        ("edge (16x16, 256 PEs)", presets::edge()),
        ("cloud (32x64, 2048 PEs)", presets::cloud(32, 64)),
    ] {
        let config = OrchestratorConfig {
            samples: effort.samples(),
            seed: 42,
            ..OrchestratorConfig::default()
        };
        let orchestrator = NetworkOrchestrator::with_config(&arch, &model, &constraints, config);
        match orchestrator.run(&graph) {
            Ok(result) => {
                println!("--- {label} ---");
                print!("{}", result.per_layer_table().render());
                println!("{}\n", result.summary());
            }
            Err(e) => println!("--- {label} --- failed: {e}\n"),
        }
    }
}
