//! Case study 2 (paper §V-B): **mapping exploration** — how do flexible
//! accelerators (MAERI / Eyeriss_v2-style) benefit from reconfiguring
//! their aspect ratio per workload?
//!
//! Regenerates Fig. 3 (the mapping sweep showing why search matters) and
//! Fig. 10 (EDP vs aspect ratio for the Table IV DNN workloads on the
//! edge and cloud flexible accelerators, MAESTRO-style cost model).
//!
//! Run: `cargo run --release --example mapping_exploration`

use union::experiments::{fig10_aspect_ratio, fig3_mapping_sweep, Effort};

fn main() {
    let effort = if std::env::args().any(|a| a == "--thorough") {
        Effort::Thorough
    } else {
        Effort::Fast
    };

    // Fig. 3: different mappings of one layer span orders of magnitude
    let (fig3, raw) = fig3_mapping_sweep(effort);
    print!("{}", fig3.render());
    let edps: Vec<f64> = raw.iter().map(|r| r.2).collect();
    let spread = edps.iter().copied().fold(f64::MIN, f64::max)
        / edps.iter().copied().fold(f64::MAX, f64::min);
    println!("EDP spread across mappings: {spread:.0}x (the cost of a bad mapping)\n");

    // Fig. 10: aspect-ratio exploration
    let (edge, cloud, series) = fig10_aspect_ratio(effort);
    print!("{}", edge.render());
    println!();
    print!("{}", cloud.render());

    // the paper's observation: EDP saturates once utilization is
    // maximized; balanced ratios are best-or-tied for most workloads
    let mut balanced_best = 0;
    let mut total = 0;
    for (name, points) in &series {
        let (best_label, _) = points
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let balanced = if name.starts_with("edge") { "16x16" } else { "32x64" };
        // "best or tied": within 5% of the minimum
        let balanced_val = points
            .iter()
            .find(|(l, _)| l == balanced)
            .map(|(_, v)| *v)
            .unwrap_or(f64::INFINITY);
        total += 1;
        if balanced_val <= 1.05 {
            balanced_best += 1;
        }
        let _ = best_label;
    }
    println!(
        "\nbalanced aspect ratio best-or-tied (within 5%) for {balanced_best}/{total} \
         workload×accelerator combinations (paper: \"for most of the cases\")"
    );
}
