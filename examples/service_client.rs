//! Mapping-as-a-service, end to end in one process: start `union
//! serve`'s server on an ephemeral port, drive it with the JSON-lines
//! client, and watch identical jobs coalesce and repeat jobs come back
//! from the cache.
//!
//!     cargo run --release --example service_client
//!
//! Against a long-running daemon the client half of this is just
//! `union client search --workload gemm:256x64x512 --arch edge`.

use union::mappers::Objective;
use union::service::{client_request, client_request_with, JobSpec, Request, ServeConfig, Server};

fn main() -> Result<(), String> {
    // an ephemeral in-memory server; a real deployment runs
    // `union serve --port 7415 --cache results.jsonl` instead
    let server = Server::bind(ServeConfig { port: 0, ..ServeConfig::default() })?;
    let addr = server.local_addr()?.to_string();
    println!("serving on {addr}");
    let daemon = std::thread::spawn(move || server.run());

    let spec = JobSpec {
        workload: "gemm:256x64x512".into(),
        arch: "edge".into(),
        cost: "analytical".into(),
        objective: Objective::Edp,
        samples: 300,
        seed: 42,
        constraints: String::new(),
    };

    // first query: a fresh search on some shard, streaming anytime
    // progress snapshots while it runs
    let first = client_request_with(
        &addr,
        &Request::Search { id: Some("q1".into()), spec: spec.clone(), progress: true },
        &mut |ev| {
            println!(
                "  progress: evaluated={} best={}",
                ev.num("evaluated").unwrap_or(0.0),
                ev.num("best_score")
                    .map(|s| format!("{s:.4e}"))
                    .unwrap_or_else(|| "-".into()),
            )
        },
    )?;
    println!(
        "first answer:  cached={} score={:.4e} ({} candidates evaluated)",
        first.bool_field("cached").unwrap(),
        first.num("score").unwrap(),
        first.num("evaluated").unwrap(),
    );

    // same job again: served from the result cache, bit-identical
    let second = client_request(
        &addr,
        &Request::Search { id: Some("q2".into()), spec, progress: false },
    )?;
    println!(
        "second answer: cached={} score={:.4e}",
        second.bool_field("cached").unwrap(),
        second.num("score").unwrap(),
    );
    assert_eq!(
        first.num("score").unwrap().to_bits(),
        second.num("score").unwrap().to_bits(),
        "cache must reproduce the search bit-exactly"
    );

    // counters, then a graceful drain
    let status = client_request(&addr, &Request::Status { id: None })?;
    println!(
        "status: requests={} searched={} cache_hits={}",
        status.num("requests").unwrap(),
        status.num("searched").unwrap(),
        status.num("cache_hits").unwrap(),
    );
    let bye = client_request(&addr, &Request::Shutdown { id: None })?;
    println!("shutdown ok={}", bye.bool_field("ok").unwrap());
    daemon.join().map_err(|_| "server thread panicked")??;
    Ok(())
}
