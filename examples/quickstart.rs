//! Quickstart: evaluate a GEMM on the Table V edge accelerator with two
//! different mappers × two different cost models — the plug-and-play
//! interoperability that is Union's core claim.
//!
//! Run: `cargo run --release --example quickstart`

use union::prelude::*;

fn main() {
    // 1. a workload, as the frontend would produce it
    let workload = Workload::gemm("quickstart_gemm", 256, 256, 256);
    let problem = workload.problem();
    println!("{problem}");

    // 2. a logical architecture (Table V edge: 256 PEs, 16x16)
    let arch = presets::edge();
    println!("{arch}");

    // 3. the map space (no constraint file: fully-flexible accelerator)
    let constraints = Constraints::default();
    let space = MapSpace::new(&problem, &arch, &constraints);
    println!("tiling space ≈ {:.2e} candidates\n", space.tiling_space_size());

    // 4. any mapper × any cost model
    let analytical = AnalyticalModel::new(EnergyTable::default_8bit());
    let maestro = MaestroModel::new(EnergyTable::default_8bit());
    let mappers: Vec<(&str, Box<dyn Mapper>)> = vec![
        ("random", Box::new(RandomMapper::new(2_000, 42))),
        ("genetic", Box::new(GeneticMapper::new(60, 10, 42))),
    ];
    let models: Vec<(&str, &dyn CostModel)> = vec![
        ("analytical (Timeloop-style)", &analytical),
        ("maestro    (MAESTRO-style) ", &maestro),
    ];
    for (mname, mapper) in &mappers {
        for (cname, model) in &models {
            let best = mapper
                .search(&space, *model)
                .expect("search found no legal mapping");
            println!(
                "mapper={mname:<8} cost={cname}  best EDP = {:.3e} J·s  \
                 (util {:>5.1}%, {} mappings evaluated)",
                best.score,
                best.cost.utilization * 100.0,
                best.evaluated
            );
        }
    }

    // 5. inspect the winner in the paper's loop-nest form
    let best = RandomMapper::new(2_000, 42)
        .search(&space, &analytical)
        .unwrap();
    println!(
        "\nbest mapping ({} partitioned, {} PEs):\n{}",
        best.mapping.partition_name(&problem),
        best.mapping.pes_used(),
        union::mapping::render_loop_nest(&best.mapping, &problem, &arch)
    );
}
