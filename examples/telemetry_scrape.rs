//! Telemetry end to end in one process: start `union serve`'s server on
//! an ephemeral port, generate a little traffic, then scrape the
//! metrics registry (counters + phase histograms + Prometheus text) and
//! replay the flight recorder over the same wire protocol.
//!
//!     cargo run --release --example telemetry_scrape
//!
//! Against a long-running daemon the scraping half of this is just
//! `union metrics` / `union trace --follow`.

use union::mappers::Objective;
use union::service::{client_request, JobSpec, Json, Request, ServeConfig, Server};
use union::telemetry::HistogramSnapshot;

fn spec(m: u64) -> JobSpec {
    JobSpec {
        workload: format!("gemm:{m}x32x64"),
        arch: "edge".into(),
        cost: "analytical".into(),
        objective: Objective::Edp,
        samples: 200,
        seed: 42,
        constraints: String::new(),
    }
}

fn main() -> Result<(), String> {
    let server = Server::bind(ServeConfig { port: 0, ..ServeConfig::default() })?;
    let addr = server.local_addr()?.to_string();
    println!("serving on {addr}");
    let daemon = std::thread::spawn(move || server.run());

    // traffic: two fresh searches and one cache hit
    for m in [64, 96, 64] {
        let r = client_request(
            &addr,
            &Request::Search { id: None, spec: spec(m), progress: false },
        )?;
        println!(
            "search gemm:{m}x32x64 -> cached={} score={:.4e}",
            r.bool_field("cached").unwrap_or(false),
            r.num("score").unwrap_or(f64::NAN),
        );
    }

    // one metrics scrape returns the whole registry: counters from
    // every MetricSource, histograms, and ready-to-serve Prometheus text
    let metrics = client_request(&addr, &Request::Metrics { id: Some("m1".into()) })?;
    let counters = metrics.get("counters").ok_or("metrics without counters")?;
    println!("\ncounters of note:");
    for name in ["broker_requests", "broker_searched", "broker_cache_hits", "engine_scored"] {
        println!("  {name} = {}", counters.num(name).unwrap_or(0.0));
    }

    println!("\nsearch-phase spans (log2-bucketed, microseconds):");
    if let Some(Json::Obj(hists)) = metrics.get("histograms") {
        for (name, h) in hists {
            if !name.starts_with("engine_phase_") {
                continue;
            }
            let snap = HistogramSnapshot {
                count: h.u64_field("count").unwrap_or(0),
                sum: h.u64_field("sum").unwrap_or(0),
                buckets: h
                    .arr("buckets")
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|pair| match pair {
                        Json::Arr(v) => match (v.first(), v.get(1)) {
                            (Some(Json::Num(i)), Some(Json::Num(n))) => {
                                Some((*i as usize, *n as u64))
                            }
                            _ => None,
                        },
                        _ => None,
                    })
                    .collect(),
            };
            println!(
                "  {name}: n={} mean={:.1}us p95<={}us",
                snap.count,
                snap.mean(),
                snap.quantile_bound(0.95),
            );
        }
    }

    let prom = metrics.str("prom").unwrap_or("");
    println!(
        "\nPrometheus text: {} lines (first: {})",
        prom.lines().count(),
        prom.lines().next().unwrap_or("-"),
    );

    // the flight recorder holds the recent structured events — here the
    // cache misses/hit and job admissions from the traffic above
    let trace = client_request(
        &addr,
        &Request::Trace { id: Some("t1".into()), since: None, limit: Some(16) },
    )?;
    println!("\nflight recorder (next_since={}):", trace.num("next_since").unwrap_or(0.0));
    for ev in trace.arr("events").unwrap_or(&[]) {
        println!(
            "  #{} +{}us {} {}",
            ev.num("seq").unwrap_or(0.0),
            ev.num("t_us").unwrap_or(0.0),
            ev.str("event").unwrap_or("?"),
            ev.str("detail").unwrap_or(""),
        );
    }

    let bye = client_request(&addr, &Request::Shutdown { id: None })?;
    assert_eq!(bye.bool_field("ok"), Some(true));
    daemon.join().map_err(|_| "server thread panicked")??;
    Ok(())
}
