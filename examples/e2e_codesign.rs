//! **End-to-end driver**: exercises every layer of the stack on a real
//! small workload, proving they compose:
//!
//! 1. *frontend* — build the intensli2 contraction (COMET/TA route) and a
//!    DLRM layer (TensorFlow/TOSA route) as mini-MLIR modules;
//! 2. *lowering* — TOSA/TA → Linalg → Affine, with conformability passes
//!    routing each problem to compatible cost models;
//! 3. *abstractions* — extract Union problems, build map spaces on the
//!    cloud accelerator;
//! 4. *optimizer* — search mappings with two mappers × two cost models,
//!    choose the algorithm (native vs TTGT) by predicted EDP;
//! 5. *runtime* — execute the AOT-compiled JAX/Pallas artifacts via PJRT
//!    (Layer-1 Pallas GEMM inside Layer-2 JAX graphs), numerically
//!    validating that the TTGT and im2col rewrites compute the same
//!    tensors the native algorithms do, and comparing measured wall-clock
//!    against the cost model's predicted cycle counts.
//!
//! Requires `make artifacts` first. Results recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_codesign`

use union::experiments::{portfolio_search, Effort};
use union::ir::{check_loop_level, check_operation_level};
use union::prelude::*;
use union::report::Table;

fn main() {
    let effort = Effort::Fast;

    // ---- 1+2: frontend + lowering + conformability ----
    println!("=== stage 1: frontend & progressive lowering ===");
    let tc = union::frontend::tccg_problem(&union::frontend::TCCG[0], 16);
    let dlrm = union::frontend::dlrm_layers().remove(1);
    for (w, ttgt) in [(&tc, false), (&tc, true), (&dlrm, false)] {
        let affine = w.lower(ttgt);
        let loop_ok = check_loop_level(&affine);
        let op_ok = check_operation_level(&affine, MaestroModel::supported_operations());
        println!(
            "{:<22} ttgt={:<5} loop-level: {:<42} op-level(maestro): {}",
            w.name,
            ttgt,
            format!("{loop_ok:?}"),
            if op_ok.is_ok() { "conformable" } else { "NOT conformable" }
        );
    }

    // ---- 3+4: Union problem, map space, algorithm choice ----
    println!("\n=== stage 2: optimizer (algorithm exploration on cloud 32x64) ===");
    let arch = presets::cloud(32, 64);
    let model = AnalyticalModel::new(EnergyTable::default_8bit());
    let cons = Constraints::memory_target_style();

    let native_p = tc.problem();
    let native_space = MapSpace::new(&native_p, &arch, &cons);
    let native = portfolio_search(&native_space, &model, effort, 7).expect("native search");

    let plan = union::frontend::ttgt_gemm(&tc).unwrap();
    let gemm_p = plan.gemm_workload("intensli2_ttgt").problem();
    let gemm_space = MapSpace::new(&gemm_p, &arch, &cons);
    let ttgt = portfolio_search(&gemm_space, &model, effort, 13).expect("ttgt search");

    let mut t = Table::new(
        "algorithm choice for intensli2 (TDS=16)",
        &["algorithm", "EDP (J*s)", "cycles", "PEs used", "decision"],
    );
    let winner = if ttgt.score < native.score { "TTGT" } else { "native" };
    for (name, r) in [("native", &native), ("TTGT->GEMM", &ttgt)] {
        t.row(vec![
            name.into(),
            format!("{:.3e}", r.score),
            format!("{:.3e}", r.cost.cycles),
            r.mapping.pes_used().to_string(),
            if (name == "TTGT->GEMM") == (winner == "TTGT") { "<- chosen" } else { "" }.into(),
        ]);
    }
    print!("{}", t.render());

    // ---- 5: execute through PJRT and cross-validate ----
    println!("\n=== stage 3: runtime execution (PJRT, AOT Pallas artifacts) ===");
    let dir = union::runtime::artifacts_dir();
    if !union::runtime::artifacts_available() {
        eprintln!(
            "artifacts not built (run `make artifacts`); skipping runtime stage"
        );
        std::process::exit(2);
    }
    if !union::runtime::runtime_available() {
        eprintln!("built without the `pjrt` feature; skipping runtime stage");
        std::process::exit(2);
    }
    union::runtime::validate_artifacts(&dir).expect("artifact validation failed");

    // measured vs predicted for the chosen algorithm's GEMM
    println!("\n=== stage 4: measured vs modeled ===");
    let rt = union::runtime::Runtime::cpu().expect("pjrt client");
    let exe = rt.load_artifact(&dir, "tc_intensli2_ttgt").expect("load ttgt artifact");
    let tds = 16usize;
    let a = union::runtime::random_tensor(tds * tds * tds * tds, 1);
    let b = union::runtime::random_tensor(tds * tds, 2);
    // warm up, then measure
    let _ = exe.run_f32(&[(&a, &[tds, tds, tds, tds]), (&b, &[tds, tds])]).unwrap();
    let run = exe.run_f32(&[(&a, &[tds, tds, tds, tds]), (&b, &[tds, tds])]).unwrap();
    let macs = native_p.total_macs();
    println!(
        "intensli2 TTGT on CPU-PJRT: {:.3} ms wall ({:.2e} MACs, {:.3} GMAC/s)",
        run.seconds * 1e3,
        macs as f64,
        macs as f64 / run.seconds / 1e9
    );
    println!(
        "cost model prediction for the cloud accelerator: {:.3e} cycles @1GHz = {:.3} us \
         (a {}-PE spatial accelerator, not this CPU — the model predicts the target, \
         the runtime proves numerical correctness)",
        ttgt.cost.cycles,
        ttgt.cost.latency_s() * 1e6,
        arch.num_pes()
    );

    println!("\ne2e driver: all stages composed successfully");
}
