//! Case study 1 (paper §V-A): **algorithm exploration** — should a tensor
//! contraction run natively or be rewritten to GEMM via TTGT?
//!
//! Regenerates Fig. 8 (EDP for the three TCCG contractions at two tensor
//! dimension sizes on the cloud accelerator) and Fig. 9 (the optimal
//! Union mappings for intensli2 at TDS=16, native vs GEMM).
//!
//! Run: `cargo run --release --example algorithm_exploration`

use union::experiments::{fig8_algorithm_exploration, fig9_mappings, Effort};
use union::report::bar_chart;

fn main() {
    let effort = if std::env::args().any(|a| a == "--thorough") {
        Effort::Thorough
    } else {
        Effort::Fast
    };

    let (table, points) = fig8_algorithm_exploration(effort);
    print!("{}", table.render());

    // the paper's observation: TTGT must win every TDS=16 case because
    // native under-utilizes the 32x64 array when all extents are 16
    let labels: Vec<String> = points
        .iter()
        .flat_map(|p| {
            [
                format!("{}/{} native", p.problem, p.tds),
                format!("{}/{} TTGT", p.problem, p.tds),
            ]
        })
        .collect();
    let values: Vec<f64> = points
        .iter()
        .flat_map(|p| [p.native_edp, p.ttgt_edp])
        .collect();
    println!("\n{}", bar_chart("Fig 8: EDP (log scale)", &labels, &values, 48));

    let small_tds_ttgt_wins = points
        .iter()
        .filter(|p| p.tds == 16)
        .all(|p| p.ttgt_edp < p.native_edp);
    println!(
        "TTGT wins all TDS=16 cases (paper's observation): {}",
        if small_tds_ttgt_wins { "YES" } else { "NO" }
    );

    println!("\n{}", fig9_mappings(effort));
}
