//! Case study 3 (paper §V-C): **hardware exploration** — the impact of
//! chipletization. A 16-chiplet, 4096-PE package (Simba-like) is swept
//! over the per-chiplet DRAM→global-buffer fill bandwidth.
//!
//! Regenerates Fig. 11: EDP drops steeply with fill bandwidth, then
//! saturates once the workload's data reuse makes it compute-bound;
//! high-reuse layers (ResNet50-2, 3×3) saturate earliest.
//!
//! Run: `cargo run --release --example hardware_exploration`

use union::experiments::{fig11_chiplet_bandwidth, Effort, FIG11_FILL_BW};

fn main() {
    let effort = if std::env::args().any(|a| a == "--thorough") {
        Effort::Thorough
    } else {
        Effort::Fast
    };

    let (table, series) = fig11_chiplet_bandwidth(effort);
    print!("{}", table.render());

    // saturation analysis: first bandwidth where EDP is within 10% of the
    // final (highest-bandwidth) value
    println!("\nsaturation points (EDP within 10% of the 32 GB/s value):");
    for (name, points) in &series {
        let last = points.last().map(|(_, v)| *v).unwrap_or(f64::NAN);
        let sat = points
            .iter()
            .zip(FIG11_FILL_BW.iter())
            .find(|((_, v), _)| *v <= last * 1.10)
            .map(|(_, bw)| *bw);
        match sat {
            Some(bw) => println!("  {name:<12} saturates at ~{bw} GB/s"),
            None => println!("  {name:<12} does not saturate in the swept range"),
        }
    }
    println!(
        "\npaper's observation: ResNet50-2 saturates ~2 GB/s (high reuse), \
         others between 6-12 GB/s"
    );
}
