"""AOT path: every registered artifact lowers to parseable HLO text with
the expected entry signature, without touching the filesystem beyond tmp.
"""

import jax
import pytest

from compile import aot


@pytest.mark.parametrize("name", sorted(aot.ARTIFACTS))
def test_artifact_lowers_to_hlo_text(name):
    fn, args = aot.ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple
    assert "tuple" in text


def test_build_writes_files(tmp_path):
    aot.build(str(tmp_path), only=["gemm_128"])
    out = tmp_path / "gemm_128.hlo.txt"
    assert out.exists()
    assert out.read_text().startswith("HloModule")


def test_artifact_registry_covers_runtime_contract():
    # rust/src/runtime/validate_artifacts expects exactly these names
    needed = {
        "gemm_128",
        "conv2d_direct",
        "conv2d_im2col",
        "tc_intensli2_native",
        "tc_intensli2_ttgt",
    }
    assert needed.issubset(set(aot.ARTIFACTS))
