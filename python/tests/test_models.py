"""L2 correctness: the algorithm-rewrite model graphs agree numerically —
the property Union's frontend relies on when choosing algorithms (§V-A).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ref_conv2d, ref_tc_intensli2


def rand(shape, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=shape), dtype=jnp.float32)


class TestConv2d:
    @pytest.mark.parametrize(
        "n,h,w,c,k,r,stride",
        [
            (2, 16, 16, 8, 16, 3, 1),
            (1, 8, 8, 4, 8, 1, 1),
            (1, 9, 9, 2, 4, 3, 2),
        ],
    )
    def test_im2col_equals_direct(self, n, h, w, c, k, r, stride):
        x = rand((n, h, w, c), 0)
        wt = rand((k, r, r, c), 1)
        (direct,) = model.conv2d_direct(x, wt, stride)
        (im2col,) = model.conv2d_im2col(x, wt, stride)
        assert direct.shape == im2col.shape
        np.testing.assert_allclose(direct, im2col, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 3),
        hw=st.integers(4, 12),
        c=st.sampled_from([1, 2, 4]),
        k=st.sampled_from([2, 4, 8]),
        r=st.sampled_from([1, 2, 3]),
        stride=st.sampled_from([1, 2]),
    )
    def test_im2col_hypothesis(self, n, hw, c, k, r, stride):
        if hw < r:
            return
        x = rand((n, hw, hw, c), 2)
        wt = rand((k, r, r, c), 3)
        (direct,) = model.conv2d_direct(x, wt, stride)
        (im2col,) = model.conv2d_im2col(x, wt, stride)
        np.testing.assert_allclose(direct, im2col, rtol=1e-4, atol=1e-4)

    def test_output_shape_matches_algorithm1(self):
        # X = (H - R)/stride + 1
        x = rand((1, 16, 16, 2), 4)
        wt = rand((4, 3, 3, 2), 5)
        (out,) = model.conv2d_im2col(x, wt, 1)
        assert out.shape == (1, 14, 14, 4)


class TestTensorContraction:
    @pytest.mark.parametrize("tds", [4, 8, 16])
    def test_ttgt_equals_native(self, tds):
        a = rand((tds, tds, tds, tds), 0)
        b = rand((tds, tds), 1)
        (native,) = model.tc_intensli2_native(a, b)
        (ttgt,) = model.tc_intensli2_ttgt(a, b)
        assert native.shape == ttgt.shape == (tds, tds, tds, tds)
        np.testing.assert_allclose(native, ttgt, rtol=1e-4, atol=1e-4)

    def test_native_matches_oracle(self):
        a = rand((8, 8, 8, 8), 2)
        b = rand((8, 8), 3)
        (native,) = model.tc_intensli2_native(a, b)
        np.testing.assert_allclose(native, ref_tc_intensli2(a, b), rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(tds=st.sampled_from([2, 4, 6, 8]), seed=st.integers(0, 100))
    def test_ttgt_hypothesis(self, tds, seed):
        a = rand((tds, tds, tds, tds), seed)
        b = rand((tds, tds), seed + 1)
        (native,) = model.tc_intensli2_native(a, b)
        (ttgt,) = model.tc_intensli2_ttgt(a, b)
        np.testing.assert_allclose(native, ttgt, rtol=1e-4, atol=1e-4)


class TestGemmModel:
    def test_gemm_model_tuple_convention(self):
        a = rand((16, 8), 0)
        b = rand((8, 4), 1)
        out = model.gemm_model(a, b)
        assert isinstance(out, tuple) and len(out) == 1
        np.testing.assert_allclose(out[0], a @ b, rtol=1e-5, atol=1e-5)

    def test_conv_oracle_sanity(self):
        # all-ones conv: each output = R*S*C
        x = jnp.ones((1, 5, 5, 3))
        w = jnp.ones((2, 3, 3, 3))
        out = ref_conv2d(x, w)
        np.testing.assert_allclose(out, np.full((1, 3, 3, 2), 27.0), rtol=1e-6)
