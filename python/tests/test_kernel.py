"""L1 correctness: the Pallas GEMM kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; fixed cases pin the MXU-aligned
configurations the artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm_pallas import gemm, pick_block, vmem_bytes
from compile.kernels.ref import ref_gemm


def rand(shape, dtype, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=shape), dtype=dtype)


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (64, 32, 16), (8, 8, 8), (1, 1, 1)])
def test_gemm_matches_ref_fixed(m, n, k):
    a = rand((m, k), jnp.float32, 0)
    b = rand((k, n), jnp.float32, 1)
    np.testing.assert_allclose(gemm(a, b), ref_gemm(a, b), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=96),
    n=st.integers(min_value=1, max_value=96),
    k=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gemm_matches_ref_hypothesis(m, n, k, seed):
    a = rand((m, k), jnp.float32, seed % 1000)
    b = rand((k, n), jnp.float32, (seed + 1) % 1000)
    np.testing.assert_allclose(gemm(a, b), ref_gemm(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([8, 16]),
)
def test_gemm_bf16_inputs(m, n, k):
    a = rand((m, k), jnp.bfloat16, 7)
    b = rand((k, n), jnp.bfloat16, 8)
    out = gemm(a, b)
    assert out.dtype == jnp.bfloat16
    ref = ref_gemm(a, b)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32),
        np.asarray(ref, dtype=np.float32),
        rtol=5e-2,
        atol=5e-2,
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=1024),
    bm=st.integers(min_value=2, max_value=48),
)
def test_explicit_blocks(m, bm):
    # any divisor pair is a legal tiling
    if m % bm != 0:
        bm = pick_block(m, bm)
    a = rand((m, 8), jnp.float32, 3)
    b = rand((8, 16), jnp.float32, 4)
    np.testing.assert_allclose(
        gemm(a, b, bm=bm, bn=16), ref_gemm(a, b), rtol=1e-4, atol=1e-4
    )


@given(n=st.integers(min_value=1, max_value=4096), t=st.integers(min_value=1, max_value=256))
@settings(max_examples=100, deadline=None)
def test_pick_block_invariants(n, t):
    b = pick_block(n, t)
    assert 1 <= b <= max(t, n if n <= t else t)
    assert n % b == 0
    assert b <= t or n <= t


def test_pick_block_prefers_mxu_tiles():
    assert pick_block(1024) == 128
    assert pick_block(4096) == 128
    assert pick_block(96) == 96
    assert pick_block(100, 64) == 50


def test_vmem_budget_for_shipped_blocks():
    # DESIGN.md §Perf: all shipped artifact shapes stay far below 16 MiB
    assert vmem_bytes(128, 128, 128) < 16 * 2**20
    assert vmem_bytes(512, 64, 1024) < 16 * 2**20
    assert vmem_bytes(4096, 16, 16) < 16 * 2**20


def test_gemm_is_jittable_and_stable():
    a = rand((32, 16), jnp.float32, 5)
    b = rand((16, 24), jnp.float32, 6)
    f = jax.jit(lambda x, y: gemm(x, y))
    np.testing.assert_allclose(f(a, b), gemm(a, b), rtol=0, atol=0)
