"""Pure-jnp correctness oracles for the Pallas kernel and the L2 models.

Everything here is deliberately written with plain ``jnp`` primitives
(``@``, ``einsum``, explicit padding arithmetic) so the kernels and model
graphs are checked against an independent implementation.
"""

import jax.numpy as jnp
from jax import lax


def ref_gemm(a, b):
    """Oracle for the Pallas GEMM: plain jnp matmul in f32."""
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    ).astype(a.dtype)


def ref_conv2d(x, w, stride: int = 1):
    """Oracle CONV2D: NHWC input, KRSC weight, valid padding.

    Uses lax.conv_general_dilated with explicit dimension numbers — an
    implementation path fully independent of the im2col+GEMM model.
    """
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "OHWI", "NHWC"),
    )


def ref_tc_intensli2(a, b):
    """Oracle for the intensli2 contraction: C[a,b,c,d] = A[d,b,e,a]·B[e,c]."""
    return jnp.einsum("dbea,ec->abcd", a, b)
