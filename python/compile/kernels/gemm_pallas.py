"""Layer-1: the tiled GEMM Pallas kernel.

This is the compute hot-spot of every workload Union evaluates (GEMM
directly; CONV2D via im2col; tensor contractions via TTGT). The tiling
mirrors a two-level Union mapping:

* the Pallas **grid** `(M/bm, N/bn)` is the mapping's `spatial_for` pair —
  each grid point is one logical cluster producing an output tile;
* the **BlockSpec** block shapes `(bm, K)` / `(K, bn)` are the cluster's
  `temporal_tile_sizes` — the VMEM-resident working set;
* the kernel body is output-stationary: the `(bm, bn)` accumulator stays
  in registers/VMEM while K streams through, exactly the `K`-innermost
  temporal order the cost model rewards for GEMM.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): block shapes are
chosen to keep the working set well under VMEM (bm=bn=128 at f32 needs
(128·K + K·128 + 128·128)·4B ≈ 192 KiB at K=128) and to feed the 128×128
MXU with full tiles. `interpret=True` is mandatory on CPU — real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute —
so we optimize structure, not interpret-mode wall-clock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of ``n`` that is ≤ ``target``.

    Keeps the grid exact for the odd shapes hypothesis throws at the
    kernel while defaulting to MXU-native 128 tiles for aligned shapes.
    """
    if n <= target:
        return n
    best = 1
    for d in range(1, target + 1):
        if n % d == 0:
            best = d
    return best


def _gemm_kernel(x_ref, y_ref, o_ref):
    """Output-stationary tile kernel: o = x @ y for one (bm, bn) tile."""
    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(a: jax.Array, b: jax.Array, *, bm: int = 0, bn: int = 0) -> jax.Array:
    """Tiled Pallas GEMM: ``a[M,K] @ b[K,N] -> [M,N]``.

    ``bm``/``bn`` override the tile sizes (0 = auto via ``pick_block``).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    assert m % bm == 0 and n % bn == 0, "blocks must divide the problem"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)


def vmem_bytes(m: int, n: int, k: int, bm: int = 0, bn: int = 0,
               dtype_bytes: int = 4) -> int:
    """Estimated per-grid-point VMEM working set of :func:`gemm`.

    Used by DESIGN.md / EXPERIMENTS.md §Perf to check the block shapes
    against the 16 MiB VMEM budget of a TPU core.
    """
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    return dtype_bytes * (bm * k + k * bn + bm * bn)
