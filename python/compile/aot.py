"""AOT compilation: lower the L2 JAX models (and the L1 Pallas kernel
inside them) to **HLO text** artifacts for the Rust runtime.

HLO *text* — not ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto instruction ids above INT_MAX,
which the xla_extension 0.5.1 behind the published ``xla`` crate rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (fn, example arg specs); shapes chosen to exercise the paper's
# workload classes at laptop scale and match rust/src/runtime validation
ARTIFACTS = {
    "gemm_128": (model.gemm_model, (spec(128, 128), spec(128, 128))),
    "gemm_512x64x1024": (model.gemm_model, (spec(512, 1024), spec(1024, 64))),
    "conv2d_direct": (model.conv2d_direct, (spec(2, 16, 16, 8), spec(16, 3, 3, 8))),
    "conv2d_im2col": (model.conv2d_im2col, (spec(2, 16, 16, 8), spec(16, 3, 3, 8))),
    "tc_intensli2_native": (
        model.tc_intensli2_native,
        (spec(16, 16, 16, 16), spec(16, 16)),
    ),
    "tc_intensli2_ttgt": (
        model.tc_intensli2_ttgt,
        (spec(16, 16, 16, 16), spec(16, 16)),
    ),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, args) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    ns = ap.parse_args()
    build(ns.out, ns.only)


if __name__ == "__main__":
    main()
