"""Layer-2: the JAX compute graphs Union's runtime executes.

Each function here is a jit-able graph that calls the Layer-1 Pallas GEMM
kernel (``kernels.gemm_pallas.gemm``) as its compute hot-spot, realizing
the frontend's algorithm choices:

* :func:`gemm_model`       — GEMM directly on the kernel;
* :func:`conv2d_direct`    — reference convolution (lax path);
* :func:`conv2d_im2col`    — CONV2D rewritten to GEMM (im2col, §II-A);
* :func:`tc_intensli2_native` — the TCCG intensli2 contraction natively;
* :func:`tc_intensli2_ttgt`   — the same contraction via the COMET TTGT
  rewrite: transpose → reshape → (Pallas) GEMM → reshape → transpose.

All are AOT-lowered to HLO text by ``compile.aot`` — Python never runs at
request time.
"""

import jax.numpy as jnp

from .kernels.gemm_pallas import gemm
from .kernels.ref import ref_conv2d


def gemm_model(a, b):
    """GEMM on the Pallas kernel. Returns a 1-tuple (AOT convention)."""
    return (gemm(a, b),)


def conv2d_direct(x, w, stride: int = 1):
    """Direct CONV2D (NHWC · KRSC), the non-rewritten algorithm."""
    return (ref_conv2d(x, w, stride),)


def conv2d_im2col(x, w, stride: int = 1):
    """CONV2D as im2col + Pallas GEMM: M = N·X·Y, N = K, K = C·R·S.

    Patch extraction is unrolled over (r, s) at trace time; the heavy
    compute lands in the Pallas kernel.
    """
    n, h, wd, c = x.shape
    k, r, s, c2 = w.shape
    assert c == c2, "channel mismatch"
    x_out = (h - r) // stride + 1
    y_out = (wd - s) // stride + 1
    patches = []
    for dr in range(r):
        for ds in range(s):
            sl = x[:, dr : dr + stride * x_out : stride, ds : ds + stride * y_out : stride, :]
            patches.append(sl)  # [N, X, Y, C]
    # [N, X, Y, R*S, C] -> [N*X*Y, R*S*C]
    pat = jnp.stack(patches, axis=3).reshape(n * x_out * y_out, r * s * c)
    # weight [K, R, S, C] -> [R*S*C, K]
    wmat = w.reshape(k, r * s * c).T
    out = gemm(pat, wmat)  # [N*X*Y, K]
    return (out.reshape(n, x_out, y_out, k),)


def tc_intensli2_native(a, b):
    """intensli2 natively: C[a,b,c,d] = A[d,b,e,a] × B[e,c]."""
    return (jnp.einsum("dbea,ec->abcd", a, b),)


def tc_intensli2_ttgt(a, b):
    """intensli2 via TTGT (§II-A): flatten to matrices, Pallas GEMM, fold
    back. free_A = (a,b,d), free_B = (c), contracted = (e) — the Table III
    GEMM is (M, N, K) = (TDS³, TDS, TDS)."""
    d, b_, e, a_ = a.shape
    e2, c = b.shape
    assert e == e2
    # A[d,b,e,a] -> [a, b, d, e] -> [(a·b·d), e]
    a_mat = jnp.transpose(a, (3, 1, 0, 2)).reshape(a_ * b_ * d, e)
    # B[e,c] is already [e, c]
    out = gemm(a_mat, b)  # [(a,b,d), c]
    # -> [a, b, d, c] -> [a, b, c, d]
    return (jnp.transpose(out.reshape(a_, b_, d, c), (0, 1, 3, 2)),)
